"""Layer blocks for the model zoo.

Every block is a pair of pure functions ``*_init(key, cfg) -> params`` and
``*_apply(params, x, cfg, ...) -> y`` operating on the residual stream
(B, S, d). Decode variants thread an explicit cache.

Blocks:
  * attention block  — GQA in the grouped-MHA view (config.padded_heads /
    kv repeated to cfg.groups), full/sliding-window, RoPE.
  * MoE block        — top-k router, capacity-bounded scatter dispatch into
    an (E, C, d) buffer, grouped expert GEMMs, weighted combine. This is
    the GShard/MaxText dropping formulation, scatter-based so no
    (T, E, C) one-hot ever materializes.
  * Mamba block      — mamba1 selective scan (chunked associative scan).
  * RG-LRU block     — RecurrentGemma recurrent block (gated linear
    recurrence + short conv), chunked scan.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import chunked_attention, decode_attention, swa_attention
from .config import ArchConfig
from .layers import apply_norm, dense, dense_init, mlp, mlp_init, norm_init, rope_qk

Params = Dict[str, Any]


def _pvary(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """``jax.lax.pvary`` where available (JAX >= 0.6 manual-axes typing);
    identity otherwise — on older JAX the varying/invariant distinction
    isn't tracked, so there is nothing to retype."""
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, axis)
    return x


# ===================================================================== #
# Attention block
# ===================================================================== #
def attn_init(key, cfg: ArchConfig, *, window: Optional[int] = None) -> Params:
    d, hd, kv = cfg.d_model, cfg.hd, cfg.n_kv_heads
    hp = cfg.padded_heads()
    ks = jax.random.split(key, 4)
    dt = cfg.jdtype
    p = {
        "wq": dense_init(ks[0], d, hp * hd, dt, bias=cfg.qkv_bias),
        "wk": dense_init(ks[1], d, kv * hd, dt, bias=cfg.qkv_bias),
        "wv": dense_init(ks[2], d, kv * hd, dt, bias=cfg.qkv_bias),
        "wo": dense_init(ks[3], hp * hd, d, dt),
    }
    # zero the padding q-heads: their q columns and out-proj rows. Forward
    # is then exactly the published n_heads model (tests/test_models_padding).
    if hp != cfg.n_heads:
        mask = _pad_head_mask(cfg)                     # (hp,) 1=real 0=pad
        colmask = jnp.repeat(mask, hd)[None, :].astype(dt)
        p["wq"]["w"] = p["wq"]["w"] * colmask
        if "b" in p["wq"]:
            p["wq"]["b"] = p["wq"]["b"] * colmask[0]
        p["wo"]["w"] = p["wo"]["w"] * colmask.T
    return p


def _pad_head_mask(cfg: ArchConfig) -> jnp.ndarray:
    """(hp,) mask — q-heads are laid out in n_kv_heads groups of g' slots,
    the first g real heads of each group are live."""
    g = cfg.n_heads // cfg.n_kv_heads
    gp = cfg.padded_heads() // cfg.n_kv_heads
    m = jnp.zeros((cfg.n_kv_heads, gp))
    m = m.at[:, :g].set(1.0)
    return m.reshape(-1)


def _project_qkv(p: Params, x: jnp.ndarray, cfg: ArchConfig):
    """x (B,S,d) -> q (B,S,G,H,hd), k/v (B,S,G,hd) with KV repeated to G."""
    B, S, _ = x.shape
    hd, G = cfg.hd, cfg.groups
    hp, kv = cfg.padded_heads(), cfg.n_kv_heads
    q = dense(p["wq"], x).reshape(B, S, G, hp // G, hd)
    k = dense(p["wk"], x).reshape(B, S, kv, hd)
    v = dense(p["wv"], x).reshape(B, S, kv, hd)
    if G != kv:
        r = G // kv
        k = jnp.repeat(k, r, axis=2)
        v = jnp.repeat(v, r, axis=2)
    return q, k, v


def attn_apply(p: Params, x: jnp.ndarray, cfg: ArchConfig, *,
               causal: bool = True, window: Optional[int] = None,
               q_offset: int = 0) -> jnp.ndarray:
    """Train/prefill attention over full sequence x (B,S,d)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg)
    if cfg.rope_theta > 0:
        pos = q_offset + jnp.arange(S)
        q, k = rope_qk(q, k, pos, pos, cfg.rope_theta)
    if window is not None:
        o = swa_attention(q, k, v, window=window, q_offset=q_offset)
    elif causal:
        o = chunked_attention(q, k, v, causal=True, q_offset=q_offset)
    else:
        o = chunked_attention(q, k, v, causal=False)
    o = o.reshape(B, S, -1)
    return dense(p["wo"], o)


def attn_prefill(p: Params, x: jnp.ndarray, cfg: ArchConfig, *,
                 window: Optional[int] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Like attn_apply but also returns the (post-RoPE) KV for the cache.

    Returns (out (B,S,d), k_cache (B,G,Sc,hd), v_cache (B,G,Sc,hd)) where
    Sc = window for SWA (rolling layout: slot = pos % window) else S.
    """
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg)
    if cfg.rope_theta > 0:
        pos = jnp.arange(S)
        q, k = rope_qk(q, k, pos, pos, cfg.rope_theta)
    if window is not None:
        o = swa_attention(q, k, v, window=window)
        W = window
        if S >= W:
            # last W positions, laid out rolling: slot i holds pos p with
            # p % W == i. Positions S-W..S-1 -> roll so slot (p % W).
            kt, vt = k[:, S - W:], v[:, S - W:]
            shift = (S - W) % W
            kc = jnp.roll(kt, shift, axis=1)
            vc = jnp.roll(vt, shift, axis=1)
        else:
            pad = W - S
            kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    else:
        o = chunked_attention(q, k, v, causal=True)
        kc, vc = k, v
    o = dense(p["wo"], o.reshape(B, S, -1))
    return o, jnp.moveaxis(kc, 1, 2), jnp.moveaxis(vc, 1, 2)


def attn_decode(p: Params, x: jnp.ndarray, k_cache: jnp.ndarray,
                v_cache: jnp.ndarray, pos: jnp.ndarray, cfg: ArchConfig, *,
                window: Optional[int] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Single-token decode. x (B,1,d); caches (B,G,Sc,hd); pos scalar.

    Writes the new KV at slot (pos % window) for SWA, pos otherwise, then
    attends over valid slots. Returns (out (B,1,d), k_cache, v_cache).
    """
    B = x.shape[0]
    q, k, v = _project_qkv(p, x, cfg)                 # q (B,1,G,H,hd)
    if cfg.rope_theta > 0:
        ppos = jnp.full((1,), 0, jnp.int32) + pos
        q, k = rope_qk(q, k, ppos, ppos, cfg.rope_theta)
    Sc = k_cache.shape[2]
    slot = pos % Sc if window is not None else pos
    kn = jnp.moveaxis(k, 1, 2)                        # (B,G,1,hd)
    vn = jnp.moveaxis(v, 1, 2)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, kn.astype(k_cache.dtype), slot, axis=2)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, vn.astype(v_cache.dtype), slot, axis=2)
    n_valid = jnp.minimum(pos + 1, Sc)
    o = decode_attention(q, k_cache, v_cache, n_valid)
    o = dense(p["wo"], o.reshape(B, 1, -1))
    return o, k_cache, v_cache


def quantize_kv(t: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-(…, slot) int8 quantization over the trailing hd axis.
    t (..., hd) -> (int8 (..., hd), scale f32 (...,))."""
    amax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def attn_decode_inplace(p: Params, x: jnp.ndarray, k_cache: jnp.ndarray,
                        v_cache: jnp.ndarray, layer: jnp.ndarray,
                        pos: jnp.ndarray, cfg: ArchConfig, *,
                        window: Optional[int] = None,
                        k_scale: Optional[jnp.ndarray] = None,
                        v_scale: Optional[jnp.ndarray] = None):
    """Like attn_decode but writes the new slot directly into the STACKED
    (L, B, G, S, hd) caches at (layer, :, :, slot) — one (B, G, 1, hd)
    write instead of re-emitting the layer's whole cache.

    int8 KV mode (§Perf qwen2 decode Q3): when k_scale/v_scale
    (L, B, G, S) are given, the caches are int8; the new slot is quantized
    on write and rows are dequantized for the attention dot — halving the
    dominant HBM term of 32k decode."""
    B = x.shape[0]
    q, k, v = _project_qkv(p, x, cfg)                 # q (B,1,G,H,hd)
    if cfg.rope_theta > 0:
        ppos = jnp.full((1,), 0, jnp.int32) + pos
        q, k = rope_qk(q, k, ppos, ppos, cfg.rope_theta)
    Sc = k_cache.shape[3]
    slot = pos % Sc if window is not None else pos
    kn = jnp.moveaxis(k, 1, 2)[None]                  # (1,B,G,1,hd)
    vn = jnp.moveaxis(v, 1, 2)[None]
    zero = jnp.zeros((), jnp.int32)
    idx = (layer, zero, zero, slot, zero)
    quant = k_scale is not None
    if quant:
        kn, ks_new = quantize_kv(kn)
        vn, vs_new = quantize_kv(vn)
        k_scale = jax.lax.dynamic_update_slice(k_scale, ks_new, idx[:-1])
        v_scale = jax.lax.dynamic_update_slice(v_scale, vs_new, idx[:-1])
    k_cache = jax.lax.dynamic_update_slice(k_cache, kn.astype(k_cache.dtype), idx)
    v_cache = jax.lax.dynamic_update_slice(v_cache, vn.astype(v_cache.dtype), idx)
    row_k = jax.lax.dynamic_index_in_dim(k_cache, layer, 0, keepdims=False)
    row_v = jax.lax.dynamic_index_in_dim(v_cache, layer, 0, keepdims=False)
    if quant:
        rks = jax.lax.dynamic_index_in_dim(k_scale, layer, 0, keepdims=False)
        rvs = jax.lax.dynamic_index_in_dim(v_scale, layer, 0, keepdims=False)
        row_k = dequantize_kv(row_k, rks, x.dtype)
        row_v = dequantize_kv(row_v, rvs, x.dtype)
    n_valid = jnp.minimum(pos + 1, Sc)
    o = decode_attention(q, row_k, row_v, n_valid)
    o = dense(p["wo"], o.reshape(B, 1, -1))
    if quant:
        return o, k_cache, v_cache, k_scale, v_scale
    return o, k_cache, v_cache


# ===================================================================== #
# Transformer block (attention + MLP), dense-family
# ===================================================================== #
def block_init(key, cfg: ArchConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": norm_init(cfg.d_model, cfg.jdtype, cfg.norm),
        "attn": attn_init(k1, cfg),
        "ln2": norm_init(cfg.d_model, cfg.jdtype, cfg.norm),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.jdtype, cfg.act),
    }


def block_apply(p: Params, x: jnp.ndarray, cfg: ArchConfig, *,
                causal: bool = True, window: Optional[int] = None) -> jnp.ndarray:
    x = x + attn_apply(p["attn"], apply_norm(p["ln1"], x), cfg,
                       causal=causal, window=window)
    x = x + mlp(p["mlp"], apply_norm(p["ln2"], x), cfg.act)
    return x


def block_prefill(p: Params, x: jnp.ndarray, cfg: ArchConfig, *,
                  window: Optional[int] = None):
    a, kc, vc = attn_prefill(p["attn"], apply_norm(p["ln1"], x), cfg,
                             window=window)
    x = x + a
    x = x + mlp(p["mlp"], apply_norm(p["ln2"], x), cfg.act)
    return x, kc, vc


def block_decode(p: Params, x: jnp.ndarray, kc, vc, pos, cfg: ArchConfig, *,
                 window: Optional[int] = None):
    a, kc, vc = attn_decode(p["attn"], apply_norm(p["ln1"], x), kc, vc, pos,
                            cfg, window=window)
    x = x + a
    x = x + mlp(p["mlp"], apply_norm(p["ln2"], x), cfg.act)
    return x, kc, vc


# ===================================================================== #
# MoE block
# ===================================================================== #
def _moe_dims(cfg: ArchConfig):
    """(E_virtual, ff_virtual, split). moe_ff_split=r slices each expert's
    ff into r column blocks => E*r virtual experts of ff/r each. down-proj
    halves sum, so dispatching a token to all r virtual slices of its
    routed expert computes exactly the original expert."""
    E, ff = cfg.moe.n_experts, cfg.d_ff
    r = max(1, cfg.moe_ff_split or 1)
    return E * r, ff // r, r


def moe_init(key, cfg: ArchConfig) -> Params:
    d, E = cfg.d_model, cfg.moe.n_experts
    Ev, ffv, _ = _moe_dims(cfg)
    dt = cfg.jdtype
    ks = jax.random.split(key, 4)
    sc = 1.0 / math.sqrt(d)
    scf = 1.0 / math.sqrt(cfg.d_ff)
    return {
        "router": (jax.random.normal(ks[0], (d, E), jnp.float32) * sc).astype(dt),
        "gate": (jax.random.normal(ks[1], (Ev, d, ffv), jnp.float32) * sc).astype(dt),
        "up": (jax.random.normal(ks[2], (Ev, d, ffv), jnp.float32) * sc).astype(dt),
        "down": (jax.random.normal(ks[3], (Ev, ffv, d), jnp.float32) * scf).astype(dt),
    }


def moe_apply(p: Params, x: jnp.ndarray, cfg: ArchConfig
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,S,d), aux_loss scalar). Capacity-dropped top-k MoE.

    Dispatch is BLOCK-LOCAL (cfg.moe_dp_blocks blocks, = the data-axis size
    in production): each block routes its own tokens into its own
    (E, C_block, d) buffer slice, with per-block capacity. This is the
    standard expert-parallel design — it keeps the scatter, the expert
    GEMMs and the combine local to each data shard (the cross-device hop
    is only the expert-axis resharding), instead of every data shard
    replicating a GLOBAL-capacity buffer (which is catastrophically
    collective-bound — see EXPERIMENTS.md §Perf grok iteration 1).
    """
    B, S, d = x.shape
    E, K = cfg.moe.n_experts, cfg.moe.top_k
    Ev, ffv, r = _moe_dims(cfg)
    T = B * S
    nb = max(1, getattr(cfg, "moe_dp_blocks", 1) or 1)
    if T % nb:
        nb = 1
    Tb = T // nb
    xb = x.reshape(nb, Tb, d)

    logits = (xb @ p["router"]).astype(jnp.float32)           # (nb, Tb, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, K)                          # (nb, Tb, K)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)

    if r > 1:
        # dispatch to every ff-slice of the routed expert (slices sum)
        idx = (idx[..., None] * r + jnp.arange(r)).reshape(nb, Tb, K * r)
        w = jnp.repeat(w, r, axis=-1)
        E, K = Ev, K * r
    C = max(1, int(math.ceil(Tb * K / E * cfg.moe.capacity_factor)))

    # rank of each (token, slot) within its expert queue, per block
    flat_idx = idx.reshape(nb, Tb * K)
    oh = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)         # (nb, Tb*K, E)
    rank = jnp.cumsum(oh, axis=1) - 1
    rank = jnp.take_along_axis(rank, flat_idx[..., None], axis=2)[..., 0]
    keep = rank < C
    slot = jnp.where(keep, flat_idx * C + rank, E * C)        # drop -> scratch

    src = jnp.repeat(xb, K, axis=1)                           # (nb, Tb*K, d)

    def scatter_block(slot_b, src_b):
        return jnp.zeros((E * C + 1, d), x.dtype).at[slot_b].add(src_b)

    buf = jax.vmap(scatter_block)(slot, src)                  # (nb, E*C+1, d)
    h = buf[:, :E * C].reshape(nb, E, C, d)
    h = _moe_constraint(h, cfg)

    pet = x.dtype
    g = jax.nn.silu(jnp.einsum("becd,edf->becf", h, p["gate"],
                               preferred_element_type=pet))
    u = jnp.einsum("becd,edf->becf", h, p["up"], preferred_element_type=pet)
    o = jnp.einsum("becf,efd->becd", g * u, p["down"],
                   preferred_element_type=pet)
    o = _moe_constraint(o, cfg)

    out_rows = jnp.concatenate(
        [o.reshape(nb, E * C, d), jnp.zeros((nb, 1, d), x.dtype)], axis=1)
    y = jnp.take_along_axis(out_rows, slot[..., None], axis=1)  # combine
    y = y * (w.reshape(nb, Tb * K, 1) * keep[..., None]).astype(x.dtype)
    y = y.reshape(nb, Tb, K, d).sum(axis=2)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e, computed PER
    # BLOCK and averaged — the distributed semantics (each data shard sees
    # only its own tokens), kept identical between the gspmd and shard_map
    # implementations (tests/test_shard_map_moe.py). Over the ORIGINAL
    # experts; virtual ff-slices are a layout detail.
    E0 = cfg.moe.n_experts
    top1 = idx[..., 0] // r if r > 1 else idx[..., 0]       # (nb, Tb)
    f = jnp.mean(jax.nn.one_hot(top1, E0, dtype=jnp.float32), axis=1)
    pmean = jnp.mean(probs, axis=1)                          # (nb, E0)
    aux = E0 * jnp.mean(jnp.sum(f * pmean, axis=-1))
    return y.reshape(B, S, d), aux


def moe_apply_shard_map(p: Params, x: jnp.ndarray, cfg: ArchConfig, mesh
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Explicit expert-parallel MoE (§Perf MoE iteration 4).

    GSPMD's handling of the dispatch scatter / combine gather all-gathers
    the (T*K, d) dispatch arrays to every model shard (measured: ~40% of
    grok train traffic even after block-local capacity). shard_map makes
    the textbook pattern explicit instead:

      * tokens are data-sharded and REPLICATED across the model axis, so
        each device dispatch-scatters its local tokens into buffers for
        the experts RESIDENT on its model shard — zero collectives;
      * local expert FFN;
      * combine gathers locally (token-slots of non-resident experts hit
        the scratch row = 0) and one token-shaped psum over "model" sums
        the expert contributions — the only collective, (T_local, d).

    Per-data-shard capacity semantics are identical to moe_apply with
    moe_dp_blocks = |data axes| (tests assert equivalence on a CPU mesh).
    """
    from jax.sharding import PartitionSpec as P
    try:
        from jax import shard_map as _shard_map
        shard_map = lambda f, **kw: _shard_map(f, **kw)
    except ImportError:
        from jax.experimental.shard_map import shard_map as _sm
        shard_map = lambda f, mesh, in_specs, out_specs: _sm(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)

    B, S, d = x.shape
    E0, K0 = cfg.moe.n_experts, cfg.moe.top_k
    Ev, ffv, r = _moe_dims(cfg)
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    n_model = mesh.shape["model"]
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    assert Ev % n_model == 0, (Ev, n_model)
    E_local = Ev // n_model

    def local_fn(xb, router, gate, up, down):
        # xb (B_loc, S, d); gate/up (E_local, d, ffv); down (E_local, ffv, d)
        Bl = xb.shape[0]
        T = Bl * S
        xf = xb.reshape(T, d)
        # retype tokens as model-varying: every shard's routing math is
        # bitwise identical, but this moves the (required) backward psum of
        # the dispatch to the TOKEN-shaped boundary dL/dxf instead of the
        # top_k-times-larger slot-shaped one (§Perf grok iteration 5).
        xf = _pvary(xf, "model")
        logits = (xf @ router).astype(jnp.float32)          # (T, E0)
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, K0)                   # (T, K0)
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
        if r > 1:
            idx = (idx[..., None] * r + jnp.arange(r)).reshape(T, K0 * r)
            w = jnp.repeat(w, r, axis=-1)
        K = K0 * r
        C = max(1, int(math.ceil(T * K / Ev * cfg.moe.capacity_factor)))

        flat_idx = idx.reshape(T * K)
        oh = jax.nn.one_hot(flat_idx, Ev, dtype=jnp.int32)
        rank = jnp.cumsum(oh, axis=0) - 1
        rank = jnp.take_along_axis(rank, flat_idx[:, None], axis=1)[:, 0]
        keep = rank < C

        m = jax.lax.axis_index("model")
        local_e = flat_idx - m * E_local                     # expert id on me
        mine = (local_e >= 0) & (local_e < E_local) & keep
        lslot = jnp.where(mine, local_e * C + rank, E_local * C)

        src = jnp.repeat(xf, K, axis=0)
        buf = jnp.zeros((E_local * C + 1, d), x.dtype).at[lslot].add(src)
        h = buf[:E_local * C].reshape(E_local, C, d)

        pet = x.dtype
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, gate,
                                   preferred_element_type=pet))
        u = jnp.einsum("ecd,edf->ecf", h, up, preferred_element_type=pet)
        o = jnp.einsum("ecf,efd->ecd", g * u, down,
                       preferred_element_type=pet)

        out_rows = jnp.concatenate(
            [o.reshape(E_local * C, d), jnp.zeros((1, d), x.dtype)], axis=0)
        y = out_rows[lslot]                                  # 0 if not mine
        y = y * (w.reshape(T * K, 1) * mine[:, None]).astype(x.dtype)
        y = y.reshape(T, K, d).sum(axis=1)
        # the ONE collective. Its cotangent is model-invariant (everything
        # downstream is replicated across "model"), so the transpose is the
        # identity — the default transpose would re-all-reduce a slot-shaped
        # f32 cotangent every layer (§Perf grok iteration 5).
        y = _psum_identity_bwd(y, "model")

        top1 = idx[:, 0] // r if r > 1 else idx[:, 0]
        f = jnp.mean(jax.nn.one_hot(top1, E0, dtype=jnp.float32), axis=0)
        pmean = jnp.mean(probs, axis=0)
        aux = E0 * jnp.sum(f * pmean)
        aux = jax.lax.pmean(aux, ("model",) + dp)   # invariant-ize copies
        return y.reshape(Bl, S, d), aux

    bspec = P(dp, None, None) if (dp and B % n_dp == 0 and B >= n_dp) \
        else P(None, None, None)
    y, aux = shard_map(
        local_fn, mesh=mesh,
        in_specs=(bspec, P(), P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(bspec, P()),
    )(x, p["router"], p["gate"], p["up"], p["down"])
    return y, aux


def _psum_identity_bwd(y: jnp.ndarray, axis: str) -> jnp.ndarray:
    """psum whose backward is the identity. Valid whenever the consumer of
    the summed value computes identically on every shard of ``axis`` (the
    cotangent is then axis-invariant and the default psum-transpose is a
    redundant all-reduce)."""
    @jax.custom_vjp
    def f(v):
        return jax.lax.psum(v, axis)

    f.defvjp(lambda v: (jax.lax.psum(v, axis), None),
             # pvary: retype the (invariant) cotangent as axis-varying —
             # no data movement, just the manual-axes bookkeeping.
             lambda _, ct: (_pvary(ct, axis),))
    return f(y)


def moe_dispatch(p: Params, x: jnp.ndarray, cfg: ArchConfig
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Route to the explicit-EP shard_map implementation when a mesh is
    active and the config requests it; pure-GSPMD path otherwise."""
    from . import runtime
    mesh = runtime.get_mesh()
    if mesh is not None and getattr(cfg, "moe_impl", "gspmd") == "shard_map":
        return moe_apply_shard_map(p, x, cfg, mesh)
    return moe_apply(p, x, cfg)


def _moe_constraint(t: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """Pin the dispatch buffer (nb, E, C, d) to (data, expert-or-ff) axes.
    Only active in production lowering (moe_dp_blocks > 1 implies a mesh)."""
    if (getattr(cfg, "moe_dp_blocks", 1) or 1) <= 1:
        return t
    from jax.sharding import PartitionSpec as P
    dp = ("pod", "data") if (cfg.moe_dp_blocks or 1) > 16 else ("data",)
    Ev, _, _ = _moe_dims(cfg)
    if Ev % 16 == 0:                      # expert-parallel (olmoe, split grok)
        spec = P(dp, "model", None, None)
    else:                                  # ff tensor-parallel (grok)
        spec = P(dp, None, None, None)
    return jax.lax.with_sharding_constraint(t, spec)


def moe_block_init(key, cfg: ArchConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": norm_init(cfg.d_model, cfg.jdtype, cfg.norm),
        "attn": attn_init(k1, cfg),
        "ln2": norm_init(cfg.d_model, cfg.jdtype, cfg.norm),
        "moe": moe_init(k2, cfg),
    }


def moe_block_apply(p: Params, x: jnp.ndarray, cfg: ArchConfig):
    x = x + attn_apply(p["attn"], apply_norm(p["ln1"], x), cfg, causal=True)
    y, aux = moe_dispatch(p["moe"], apply_norm(p["ln2"], x), cfg)
    return x + y, aux


def moe_block_prefill(p: Params, x: jnp.ndarray, cfg: ArchConfig):
    a, kc, vc = attn_prefill(p["attn"], apply_norm(p["ln1"], x), cfg)
    x = x + a
    y, _ = moe_dispatch(p["moe"], apply_norm(p["ln2"], x), cfg)
    return x + y, kc, vc


def moe_block_decode(p: Params, x: jnp.ndarray, kc, vc, pos, cfg: ArchConfig):
    a, kc, vc = attn_decode(p["attn"], apply_norm(p["ln1"], x), kc, vc, pos, cfg)
    x = x + a
    y, _ = moe_dispatch(p["moe"], apply_norm(p["ln2"], x), cfg)
    return x + y, kc, vc


# ===================================================================== #
# Mamba (mamba1 selective-scan) block
# ===================================================================== #
def _mamba_dims(cfg: ArchConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    dtr = s.dt_rank or -(-cfg.d_model // 16)
    return d_in, dtr, s.d_state, s.d_conv


def mamba_init(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    d_in, dtr, st, cw = _mamba_dims(cfg)
    dt = cfg.jdtype
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, st + 1, dtype=jnp.float32)[None, :], (d_in, 1))
    return {
        "in_proj": dense_init(ks[0], d, 2 * d_in, dt),
        "conv_w": (jax.random.normal(ks[1], (cw, d_in), jnp.float32)
                   / math.sqrt(cw)).astype(dt),
        "conv_b": jnp.zeros((d_in,), dt),
        "x_proj": dense_init(ks[2], d_in, dtr + 2 * st, dt),
        "dt_proj": dense_init(ks[3], dtr, d_in, dt, bias=True),
        "A_log": jnp.log(A),                               # (d_in, st) f32
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[4], d_in, d, dt),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv. x (B,S,d_in), w (cw,d_in).
    state (B,cw-1,d_in) holds the trailing inputs of the previous segment."""
    cw = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(cw))
    return y + b


def _selective_scan_chunk(h0, dt, Bm, Cm, A, xc):
    """One chunk of the mamba scan.
    h0 (B,d_in,st) f32; dt (B,c,d_in); Bm/Cm (B,c,st); xc (B,c,d_in).
    Returns (h_last, y (B,c,d_in))."""
    dtf = dt.astype(jnp.float32)
    Abar = jnp.exp(dtf[..., None] * A)                        # (B,c,d_in,st)
    Bx = (dtf * xc.astype(jnp.float32))[..., None] * Bm.astype(jnp.float32)[:, :, None, :]

    def comb(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    a_sc, b_sc = jax.lax.associative_scan(comb, (Abar, Bx), axis=1)
    h = b_sc + a_sc * h0[:, None]                             # (B,c,d_in,st)
    y = jnp.einsum("bcds,bcs->bcd", h, Cm.astype(jnp.float32))
    return h[:, -1], y


def mamba_apply(p: Params, x: jnp.ndarray, cfg: ArchConfig, *,
                chunk: int = 256) -> jnp.ndarray:
    """Train/prefill. x (B,S,d) -> (B,S,d)."""
    B, S, d = x.shape
    d_in, dtr, st, cw = _mamba_dims(cfg)
    xz = dense(p["in_proj"], x)
    xi, z = jnp.split(xz, 2, axis=-1)                         # (B,S,d_in)
    xc = jax.nn.silu(_causal_conv(xi, p["conv_w"], p["conv_b"]))
    dbc = dense(p["x_proj"], xc)
    dt_r, Bm, Cm = jnp.split(dbc, [dtr, dtr + st], axis=-1)
    dt = jax.nn.softplus(dense(p["dt_proj"], dt_r).astype(jnp.float32))
    A = -jnp.exp(p["A_log"])                                  # (d_in, st)

    c = min(chunk, S)
    pad = -S % c
    if pad:
        xc_p = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
        dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm_p = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm_p = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    else:
        xc_p, dt_p, Bm_p, Cm_p = xc, dt, Bm, Cm
    n = xc_p.shape[1] // c

    def step(h, inp):
        dt_i, B_i, C_i, x_i = inp
        h, y = _selective_scan_chunk(h, dt_i, B_i, C_i, A, x_i)
        return h, y

    reshape = lambda a: jnp.moveaxis(a.reshape(B, n, c, -1), 1, 0)
    h0 = jnp.zeros((B, d_in, st), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (reshape(dt_p), reshape(Bm_p),
                                    reshape(Cm_p), reshape(xc_p)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, n * c, d_in)[:, :S]
    y = y + p["D"] * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return dense(p["out_proj"], y)


def mamba_prefill(p: Params, x: jnp.ndarray, cfg: ArchConfig):
    """Returns (y, h_state (B,d_in,st) f32, conv_state (B,cw-1,d_in))."""
    B, S, d = x.shape
    d_in, dtr, st, cw = _mamba_dims(cfg)
    xz = dense(p["in_proj"], x)
    xi, z = jnp.split(xz, 2, axis=-1)
    conv_state = xi[:, S - (cw - 1):, :] if S >= cw - 1 else jnp.pad(
        xi, ((0, 0), (cw - 1 - S, 0), (0, 0)))
    xc = jax.nn.silu(_causal_conv(xi, p["conv_w"], p["conv_b"]))
    dbc = dense(p["x_proj"], xc)
    dt_r, Bm, Cm = jnp.split(dbc, [dtr, dtr + st], axis=-1)
    dt = jax.nn.softplus(dense(p["dt_proj"], dt_r).astype(jnp.float32))
    A = -jnp.exp(p["A_log"])
    c = min(256, S)
    pad = -S % c
    padf = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0))) if pad else a
    n = (S + pad) // c
    reshape = lambda a: jnp.moveaxis(padf(a).reshape(B, n, c, -1), 1, 0)

    def step(h, inp):
        dt_i, B_i, C_i, x_i = inp
        h, y = _selective_scan_chunk(h, dt_i, B_i, C_i, A, x_i)
        return h, y

    h0 = jnp.zeros((B, d_in, st), jnp.float32)
    h_last, ys = jax.lax.scan(step, h0, (reshape(dt), reshape(Bm),
                                         reshape(Cm), reshape(xc)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, n * c, d_in)[:, :S]
    y = y + p["D"] * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return dense(p["out_proj"], y), h_last, conv_state


def mamba_decode(p: Params, x: jnp.ndarray, h: jnp.ndarray,
                 conv_state: jnp.ndarray, cfg: ArchConfig):
    """Single step. x (B,1,d); h (B,d_in,st) f32; conv_state (B,cw-1,d_in).
    Returns (y (B,1,d), h, conv_state)."""
    B = x.shape[0]
    d_in, dtr, st, cw = _mamba_dims(cfg)
    xz = dense(p["in_proj"], x)
    xi, z = jnp.split(xz, 2, axis=-1)                         # (B,1,d_in)
    window = jnp.concatenate([conv_state.astype(x.dtype), xi], axis=1)  # (B,cw,d_in)
    xc = jax.nn.silu(jnp.einsum("bcd,cd->bd", window, p["conv_w"]) + p["conv_b"])
    conv_state = window[:, 1:]
    dbc = dense(p["x_proj"], xc)
    dt_r, Bm, Cm = jnp.split(dbc, [dtr, dtr + st], axis=-1)
    dt = jax.nn.softplus(dense(p["dt_proj"], dt_r).astype(jnp.float32))  # (B,d_in)
    A = -jnp.exp(p["A_log"])
    Abar = jnp.exp(dt[..., None] * A)                          # (B,d_in,st)
    Bx = (dt * xc.astype(jnp.float32))[..., None] * Bm.astype(jnp.float32)[:, None, :]
    h = Abar * h + Bx
    y = jnp.einsum("bds,bs->bd", h, Cm.astype(jnp.float32))
    y = y + p["D"] * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(x.dtype)
    return dense(p["out_proj"], y)[:, None, :], h, conv_state


# ===================================================================== #
# RG-LRU (RecurrentGemma) recurrent block
# ===================================================================== #
_LRU_C = 8.0


def rglru_init(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    w = cfg.hybrid.lru_width or d
    cw = cfg.hybrid.conv_width
    dt = cfg.jdtype
    ks = jax.random.split(key, 6)
    return {
        "in_x": dense_init(ks[0], d, w, dt),
        "in_gate": dense_init(ks[1], d, w, dt),
        "conv_w": (jax.random.normal(ks[2], (cw, w), jnp.float32)
                   / math.sqrt(cw)).astype(dt),
        "conv_b": jnp.zeros((w,), dt),
        "wa": dense_init(ks[3], w, w, dt, bias=True),          # recurrence gate
        "wx": dense_init(ks[4], w, w, dt, bias=True),          # input gate
        "lam": jnp.full((w,), 4.0, jnp.float32),               # Λ param
        "out": dense_init(ks[5], w, d, dt),
    }


def _rglru_scan(p, xc, h0, *, chunk=256):
    """xc (B,S,w) post-conv branch; h0 (B,w) f32. Returns (y, h_last)."""
    B, S, w = xc.shape
    r = jax.nn.sigmoid(dense(p["wa"], xc).astype(jnp.float32))
    i = jax.nn.sigmoid(dense(p["wx"], xc).astype(jnp.float32))
    log_a = -_LRU_C * jax.nn.softplus(p["lam"]) * r            # (B,S,w)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * xc.astype(jnp.float32))

    c = min(chunk, S)
    pad = -S % c
    padf = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0))) if pad else t
    n = (S + pad) // c
    resh = lambda t: jnp.moveaxis(padf(t).reshape(B, n, c, w), 1, 0)

    def comb(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    def step(h, inp):
        a_i, g_i = inp
        a_sc, b_sc = jax.lax.associative_scan(comb, (a_i, g_i), axis=1)
        hc = b_sc + a_sc * h[:, None]
        return hc[:, -1], hc

    h_last, ys = jax.lax.scan(step, h0, (resh(a), resh(gated)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, n * c, w)[:, :S]
    return y, h_last


def rglru_apply(p: Params, x: jnp.ndarray, cfg: ArchConfig,
                h0: Optional[jnp.ndarray] = None,
                conv_state: Optional[jnp.ndarray] = None,
                return_state: bool = False):
    """Full recurrent block: (gate branch) * RG-LRU(conv(x branch)) -> out."""
    B, S, _ = x.shape
    w = cfg.hybrid.lru_width or cfg.d_model
    cw = cfg.hybrid.conv_width
    xb = dense(p["in_x"], x)
    gate = jax.nn.gelu(dense(p["in_gate"], x))
    new_conv = xb[:, S - (cw - 1):, :] if S >= cw - 1 else jnp.pad(
        xb, ((0, 0), (cw - 1 - S, 0), (0, 0)))
    xc = _causal_conv(xb, p["conv_w"], p["conv_b"], state=conv_state)
    if h0 is None:
        h0 = jnp.zeros((B, w), jnp.float32)
    y, h_last = _rglru_scan(p, xc, h0)
    out = dense(p["out"], (y.astype(x.dtype) * gate))
    if return_state:
        return out, h_last, new_conv
    return out


def rglru_decode(p: Params, x: jnp.ndarray, h: jnp.ndarray,
                 conv_state: jnp.ndarray, cfg: ArchConfig):
    """x (B,1,d); h (B,w) f32; conv_state (B,cw-1,w)."""
    B = x.shape[0]
    xb = dense(p["in_x"], x)                                   # (B,1,w)
    gate = jax.nn.gelu(dense(p["in_gate"], x))
    window = jnp.concatenate([conv_state.astype(x.dtype), xb], axis=1)
    xc = jnp.einsum("bcw,cw->bw", window, p["conv_w"]) + p["conv_b"]
    conv_state = window[:, 1:]
    r = jax.nn.sigmoid(dense(p["wa"], xc).astype(jnp.float32))
    i = jax.nn.sigmoid(dense(p["wx"], xc).astype(jnp.float32))
    log_a = -_LRU_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    h = a * h + jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * xc.astype(jnp.float32))
    out = dense(p["out"], (h[:, None].astype(x.dtype) * gate))
    return out, h, conv_state
