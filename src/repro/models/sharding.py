"""PartitionSpecs for the model zoo on the production mesh.

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
"pod" composes with "data" for batch sharding; "model" carries tensor /
expert / channel parallelism.

Strategy (baseline — §Perf iterates from here):
  * embed (V, d)            -> shard d            (gather stays local)
  * lm_head (d, V)          -> shard V            (vocab-sharded logits,
                                local log-softmax + all-reduce)
  * attn wq (d, Hp*hd)      -> shard out (= q-head parallel; Hp is padded
                                so Hp*hd / model_axis is head-aligned)
  * attn wk/wv (d, KV*hd)   -> shard out (KV*hd % 16 == 0 for all archs)
  * attn wo (Hp*hd, d)      -> shard in  (row-parallel, one all-reduce)
  * mlp up/gate             -> shard ff; down -> shard in (Megatron pair)
  * MoE experts (E, d, ff)  -> expert-parallel over "model" when E % 16 == 0
                               (olmoe 64e), else tensor-parallel inside each
                               expert (grok 8e)
  * mamba / RG-LRU          -> channel-parallel: every d_inner/lru_width
                               dim over "model" (the scan is elementwise in
                               channels => zero per-step collectives)
  * KV / recurrent caches   -> batch over "data"(+"pod"), KV-slot axis
                               (= cfg.groups, sized to the model axis) over
                               "model"
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .config import ArchConfig

MODEL_AXIS = "model"


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def batch_sharded(mesh: Mesh, global_batch: int) -> bool:
    import numpy as np
    n = 1
    for a in data_axes(mesh):
        n *= mesh.shape[a]
    return global_batch % n == 0 and global_batch >= n


def batch_pspec(mesh: Mesh, global_batch: int, ndim: int) -> P:
    """P((pod,data), None, ...) when the batch divides the data axes, else
    fully replicated (long_500k's batch=1)."""
    if batch_sharded(mesh, global_batch):
        return P(data_axes(mesh), *([None] * (ndim - 1)))
    return P(*([None] * ndim))


# --------------------------------------------------------------------- #
# parameter shardings
# --------------------------------------------------------------------- #
def _expert_parallel(cfg: ArchConfig, axis_size: int) -> bool:
    if cfg.moe is None:
        return False
    n_virtual = cfg.moe.n_experts * max(1, cfg.moe_ff_split or 1)
    return n_virtual % axis_size == 0


def param_pspec(cfg: ArchConfig, path: Tuple[str, ...], ndim: int,
                axis_size: int) -> P:
    """PartitionSpec for one param leaf, identified by its tree path."""
    names = [p for p in path]
    key = ".".join(names)
    last = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    gp = names[-3] if len(names) >= 3 else ""

    def spec(*axes):
        """axes indexed from the right (negative positions)."""
        out = [None] * ndim
        for pos, ax in axes:
            out[ndim + pos] = ax
        return P(*out)

    # ---- top-level tables ---- #
    if last == "embed":
        if cfg.family == "audio":
            return spec((-2, MODEL_AXIS))        # vocab-sharded (tied head)
        return spec((-1, MODEL_AXIS))            # d-sharded
    if last == "lm_head":
        return spec((-1, MODEL_AXIS))            # vocab-sharded logits
    if last == "dec_pos":
        return P(*([None] * ndim))

    # ---- MoE experts ---- #
    if parent in ("moe",) or (cfg.moe and last in ("router",)):
        if last == "router":
            return P(*([None] * ndim))
    if cfg.moe and gp == "moe" or (cfg.moe and parent == "moe"):
        pass
    if cfg.moe and last in ("gate", "up", "down") and ndim >= 3 and parent == "moe":
        # (L, E, d, ff) / (L, E, ff, d)
        if _expert_parallel(cfg, axis_size):
            return spec((-3, MODEL_AXIS))        # expert axis
        if last == "down":
            return spec((-2, MODEL_AXIS))        # ff (contracting) dim
        return spec((-1, MODEL_AXIS))            # ff (output) dim

    # ---- attention projections ---- #
    if parent in ("wq", "wk", "wv") and last == "w":
        return spec((-1, MODEL_AXIS))
    if parent in ("wq", "wk", "wv") and last == "b":
        return spec((-1, MODEL_AXIS))
    if parent == "wo" and last == "w":
        return spec((-2, MODEL_AXIS))
    if parent == "wo" and last == "b":
        return P(*([None] * ndim))

    # ---- MLP ---- #
    if parent in ("gate", "up") and last == "w":
        return spec((-1, MODEL_AXIS))
    if parent == "down" and last == "w":
        return spec((-2, MODEL_AXIS))
    if parent in ("gate", "up", "down") and last == "b":
        return P(*([None] * ndim))

    # ---- mamba ---- #
    if parent == "in_proj" and last == "w":
        return spec((-1, MODEL_AXIS))            # (L, d, 2*d_in)
    if last == "conv_w":
        return spec((-1, MODEL_AXIS))            # (L, cw, d_in|w)
    if last == "conv_b":
        return spec((-1, MODEL_AXIS))
    if parent == "x_proj" and last == "w":
        return spec((-2, MODEL_AXIS))            # (L, d_in, dtr+2s) contract
    if parent == "dt_proj":
        return spec((-1, MODEL_AXIS))            # (L, dtr, d_in) / bias
    if last == "A_log":
        return spec((-2, MODEL_AXIS))            # (L, d_in, st)
    if last == "D":
        return spec((-1, MODEL_AXIS))
    if parent == "out_proj" and last == "w":
        return spec((-2, MODEL_AXIS))            # (L, d_in, d)

    # ---- RG-LRU ---- #
    if parent in ("in_x", "in_gate") and last == "w":
        return spec((-1, MODEL_AXIS))            # (P, d, w)
    if parent in ("wa", "wx"):
        # (P, w, w) gate matmuls contract the sharded channel dim; shard
        # the output so gates stay channel-sharded (one all-gather of xc).
        return spec((-1, MODEL_AXIS))
    if last == "lam":
        return spec((-1, MODEL_AXIS))
    if parent == "out" and last == "w":
        return spec((-2, MODEL_AXIS))            # (P, w, d)

    # ---- norms, scalars, everything else ---- #
    return P(*([None] * ndim))


def param_shardings(cfg: ArchConfig, mesh: Mesh, params_tree) -> Any:
    """NamedSharding pytree matching ``params_tree`` (arrays or SDS)."""
    axis_size = mesh.shape[MODEL_AXIS]

    def one(path, leaf):
        names = tuple(_key_name(k) for k in path)
        return NamedSharding(mesh, param_pspec(cfg, names, leaf.ndim, axis_size))

    return jax.tree_util.tree_map_with_path(one, params_tree)


def _key_name(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "name"):
        return str(k.name)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


# --------------------------------------------------------------------- #
# batch & cache shardings
# --------------------------------------------------------------------- #
def batch_shardings(mesh: Mesh, global_batch: int, batch_tree) -> Any:
    def one(leaf):
        return NamedSharding(mesh, batch_pspec(mesh, global_batch, leaf.ndim))
    return jax.tree.map(one, batch_tree)


def cache_shardings(cfg: ArchConfig, mesh: Mesh, global_batch: int,
                    cache_tree) -> Any:
    """Caches carry a leading L (or periods) axis, then batch.

    Rule per leaf (by shape):
      * axis 1 is batch -> data axes (if divisible)
      * the KV-slot axis (size cfg.groups) or a channel axis divisible by
        the model-axis size -> "model".
    """
    axis_size = mesh.shape[MODEL_AXIS]
    dp = data_axes(mesh)
    shard_batch = batch_sharded(mesh, global_batch)

    def one(leaf):
        spec = [None] * leaf.ndim
        # find batch axis: first axis whose size == global_batch (skip axis 0
        # which is the layer stack unless it equals the batch itself).
        b_ax = None
        for i, s in enumerate(leaf.shape):
            if s == global_batch and i <= 1:
                b_ax = i
                break
        if b_ax is not None and shard_batch and global_batch > 1:
            spec[b_ax] = dp
        # model axis: prefer the KV-slot axis (== groups), else the largest
        # trailing channel axis divisible by axis_size.
        m_ax = None
        start = (b_ax + 1) if b_ax is not None else 1
        for i in range(start, leaf.ndim):
            if leaf.shape[i] == cfg.groups and cfg.groups % axis_size == 0:
                m_ax = i
                break
        if m_ax is None:
            best = -1
            for i in range(start, leaf.ndim):
                if leaf.shape[i] % axis_size == 0 and leaf.shape[i] > best:
                    best = leaf.shape[i]
                    m_ax = i
            if best < axis_size:
                m_ax = None
        if m_ax is not None:
            spec[m_ax] = MODEL_AXIS
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, cache_tree)


def replicated(mesh: Mesh, tree) -> Any:
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
