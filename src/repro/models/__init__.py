from .config import ArchConfig, HybridConfig, MoEConfig, SSMConfig
from .zoo import ARCH_IDS, FAMILIES, build, get_config, get_model, normalize_arch_id

__all__ = [
    "ArchConfig", "MoEConfig", "SSMConfig", "HybridConfig",
    "ARCH_IDS", "FAMILIES", "build", "get_config", "get_model",
    "normalize_arch_id",
]
