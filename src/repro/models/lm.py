"""Shared LM scaffolding: embedding, scan-over-layers, loss, decode plumbing.

All models expose the same surface (used by launch/dryrun, tests, examples):

    model.init(key)                       -> params pytree
    model.loss(params, batch)             -> (scalar loss, metrics dict)
    model.prefill(params, batch)          -> (last_logits, cache)
    model.decode_step(params, cache, token, pos) -> (logits, cache)
    model.batch_spec(shape)               -> ShapeDtypeStruct pytree (inputs)
    model.cache_spec(batch, seq)          -> ShapeDtypeStruct pytree

Layers are stacked on a leading L axis and executed with ``jax.lax.scan``
so compile time and HLO size are depth-independent (this is what makes an
88-layer 123B dry-run compile on one CPU core). ``cfg.remat == "full"``
wraps the scan body in ``jax.checkpoint``.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import apply_norm, norm_init

Params = Dict[str, Any]


def embed_init(key, n: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (n, d), jnp.float32) * 0.02).astype(dtype)


def xent(logits: jnp.ndarray, labels: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Cross-entropy with label -1 = ignore. logits (B,S,V), labels (B,S)."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    n = jnp.maximum(mask.sum(), 1.0)
    loss = jnp.sum((lse - ll) * mask) / n
    acc = jnp.sum((jnp.argmax(lf, -1) == labels) * mask) / n
    return loss, acc


def maybe_remat(fn: Callable, cfg: ArchConfig) -> Callable:
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return fn


def scan_layers(stacked: Params, x: jnp.ndarray, body: Callable,
                cfg: ArchConfig, with_aux: bool = False):
    """body(layer_params, x) -> x  (or (x, aux) when with_aux)."""
    if with_aux:
        body_r = maybe_remat(body, cfg)

        def f2(carry, p):
            x, aux = carry
            x, a = body_r(p, x)
            return (x, aux + a), None
        (x, aux), _ = jax.lax.scan(f2, (x, jnp.asarray(0.0, jnp.float32)), stacked)
        return x, aux

    body_r = maybe_remat(body, cfg)

    def f(x, p):
        return body_r(p, x), None
    x, _ = jax.lax.scan(f, x, stacked)
    return x


def scan_prefill(stacked: Params, x: jnp.ndarray, body: Callable):
    """body(p, x) -> (x, kc, vc); returns (x, (L,...) caches)."""
    def f(x, p):
        x, kc, vc = body(p, x)
        return x, (kc, vc)
    x, (kcs, vcs) = jax.lax.scan(f, x, stacked)
    return x, kcs, vcs


def scan_decode(stacked: Params, caches: Tuple, x: jnp.ndarray, body: Callable):
    """body(p, per-layer cache leaves..., x) -> (x, new leaves...).
    caches: tuple of arrays with leading L axis."""
    def f(x, inp):
        p = inp[0]
        x, *new = body(p, x, *inp[1:])
        return x, tuple(new)
    x, new_caches = jax.lax.scan(f, x, (stacked,) + tuple(caches))
    return x, new_caches


def loop_decode_inplace(stacked: Params, caches: Tuple[jnp.ndarray, ...],
                        x: jnp.ndarray, body: Callable):
    """Decode over layers with IN-PLACE slot writes on stacked
    (L, B, G, S, ...) caches.

    scan_decode re-emits every layer's full cache as a stacked scan output
    — a whole-cache copy per token, which made 32k-decode temp traffic ~4x
    the cache size (§Perf qwen2 decode iteration). Here the caches are
    loop-carried and each layer writes only its one new slot via
    dynamic_update_slice, so a donated cache updates in place.

    body(p_i, x, *caches, layer_idx) -> (x, *caches)
    """
    L = caches[0].shape[0]

    def f(i, val):
        x, cs = val
        p_i = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
            stacked)
        x, *cs = body(p_i, x, *cs, i)
        return (x, tuple(cs))

    x, caches = jax.lax.fori_loop(0, L, f, (x, tuple(caches)))
    return x, caches


class BaseLM:
    """Decoder-only scaffold shared by dense / moe / ssm / hybrid / vlm."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # ---------------- params ---------------- #
    def init_layers(self, key) -> Params:
        raise NotImplementedError

    def init(self, key) -> Params:
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        p = {
            "embed": embed_init(k1, cfg.padded_vocab, cfg.d_model, cfg.jdtype),
            "layers": self.init_layers(k2),
            "ln_f": norm_init(cfg.d_model, cfg.jdtype, cfg.norm),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = embed_init(k3, cfg.padded_vocab, cfg.d_model,
                                      cfg.jdtype).T
        return p

    def logits(self, params: Params, h: jnp.ndarray) -> jnp.ndarray:
        w = params["embed"].T if self.cfg.tie_embeddings else params["lm_head"]
        return h @ w

    # ---------------- forward hooks (family-specific) ---------------- #
    def backbone(self, params, x):
        """Full-sequence residual stream (train). Returns (h, aux)."""
        raise NotImplementedError

    def backbone_prefill(self, params, x, cache_len=None):
        """Returns (h, cache). ``cache_len`` pads attention caches with
        headroom for subsequent decode_step writes (serving allocates the
        max length up front)."""
        raise NotImplementedError

    def backbone_decode(self, params, cache, x, pos):
        """Returns (h (B,1,d), cache)."""
        raise NotImplementedError

    def embed_batch(self, params, batch) -> jnp.ndarray:
        return params["embed"][batch["tokens"]]

    # ---------------- public API ---------------- #
    def loss(self, params: Params, batch: Dict[str, jnp.ndarray]):
        x = self.embed_batch(params, batch)
        h, aux = self.backbone(params, x)
        h = apply_norm(params["ln_f"], h)
        logits = self.logits(params, h)
        loss, acc = xent(logits, batch["labels"])
        total = loss + 0.01 * aux
        return total, {"ce": loss, "aux": aux, "acc": acc}

    def prefill(self, params: Params, batch: Dict[str, jnp.ndarray],
                cache_len: Optional[int] = None):
        x = self.embed_batch(params, batch)
        h, cache = self.backbone_prefill(params, x, cache_len)
        h = apply_norm(params["ln_f"], h[:, -1:])
        return self.logits(params, h), cache

    def decode_step(self, params: Params, cache, token: jnp.ndarray,
                    pos: jnp.ndarray):
        x = params["embed"][token]                      # (B,1,d)
        h, cache = self.backbone_decode(params, cache, x, pos)
        h = apply_norm(params["ln_f"], h)
        return self.logits(params, h), cache

    # ---------------- specs (for dry-run lowering) ---------------- #
    def batch_spec(self, batch: int, seq: int) -> Dict[str, jax.ShapeDtypeStruct]:
        return {
            "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
            "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        }

    def cache_spec(self, batch: int, seq: int):
        raise NotImplementedError

    def supports_long_context(self) -> bool:
        return False
