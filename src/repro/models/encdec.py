"""Encoder-decoder audio model — whisper-base backbone.

The modality frontend (mel spectrogram + conv downsampler) is a STUB per
the assignment: ``batch["frames"]`` carries precomputed frame embeddings
(B, S_enc, d). The transformer is real: non-causal chunked self-attention
encoder, causal decoder with cross-attention, GELU MLPs, LayerNorm,
sinusoidal encoder positions, learned decoder positions, tied softmax.

Shape mapping (see DESIGN.md): the assigned ``seq_len`` is the *encoder*
frame count; the decoder is capped at ``cfg.dec_len_cap`` (whisper: 448),
its design maximum. decode_32k therefore means: cross-attend a 32k-frame
encoder memory while decoding with a 448-slot self-attention cache.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from . import blocks
from .config import ArchConfig
from .layers import apply_norm, dense, mlp, mlp_init, norm_init, stacked_init
from .lm import BaseLM, embed_init, scan_decode, scan_layers, xent

Params = Dict[str, Any]


def sinusoid(S: int, d: int, dtype) -> jnp.ndarray:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _dec_len(seq: int, cap: int) -> int:
    return max(8, min(cap, seq // 8))


def cross_attn_apply(p: Params, x: jnp.ndarray, mem: jnp.ndarray,
                     cfg: ArchConfig) -> jnp.ndarray:
    """q from x (B,Sq,d); k/v from encoder memory (B,Sk,d)."""
    k, v = _cross_kv(p, mem, cfg)
    return _cross_attend(p, x, k, v, cfg)


def _cross_kv(p: Params, mem: jnp.ndarray, cfg: ArchConfig):
    B, Sk, _ = mem.shape
    hd, kv, G = cfg.hd, cfg.n_kv_heads, cfg.groups
    k = dense(p["wk"], mem).reshape(B, Sk, kv, hd)
    v = dense(p["wv"], mem).reshape(B, Sk, kv, hd)
    if G != kv:
        k = jnp.repeat(k, G // kv, axis=2)
        v = jnp.repeat(v, G // kv, axis=2)
    return jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2)    # (B,G,Sk,hd)


def _cross_attend(p: Params, x: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  cfg: ArchConfig) -> jnp.ndarray:
    from .attention import plain_attention
    B, Sq, _ = x.shape
    hd, G = cfg.hd, cfg.groups
    hp = cfg.padded_heads()
    q = dense(p["wq"], x).reshape(B, Sq, G, hp // G, hd)
    o = plain_attention(q, jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1),
                        causal=False)
    return dense(p["wo"], o.reshape(B, Sq, -1))


def _dec_layer_init(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": norm_init(cfg.d_model, cfg.jdtype, cfg.norm),
        "attn": blocks.attn_init(k1, cfg),
        "lnx": norm_init(cfg.d_model, cfg.jdtype, cfg.norm),
        "xattn": blocks.attn_init(k2, cfg),
        "ln2": norm_init(cfg.d_model, cfg.jdtype, cfg.norm),
        "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.jdtype, cfg.act),
    }


class EncDecModel(BaseLM):
    def init(self, key) -> Params:
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        return {
            "enc_layers": stacked_init(
                lambda k: blocks.block_init(k, cfg), ks[0], cfg.n_layers),
            "ln_e": norm_init(cfg.d_model, cfg.jdtype, cfg.norm),
            "embed": embed_init(ks[1], cfg.padded_vocab, cfg.d_model, cfg.jdtype),
            "dec_pos": embed_init(ks[2], cfg.dec_len_cap, cfg.d_model, cfg.jdtype),
            "dec_layers": stacked_init(
                lambda k: _dec_layer_init(k, cfg), ks[3], cfg.n_layers),
            "ln_f": norm_init(cfg.d_model, cfg.jdtype, cfg.norm),
        }

    # ---------------- encoder ---------------- #
    def encode(self, params, frames: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        x = frames + sinusoid(frames.shape[1], cfg.d_model, frames.dtype)

        def body(p, h):
            return blocks.block_apply(p, h, cfg, causal=False)
        h = scan_layers(params["enc_layers"], x, body, cfg)
        return apply_norm(params["ln_e"], h)

    # ---------------- decoder ---------------- #
    def _dec_embed(self, params, tokens, pos0=0):
        S = tokens.shape[1]
        return (params["embed"][tokens]
                + params["dec_pos"][pos0 + jnp.arange(S)])

    def loss(self, params, batch):
        cfg = self.cfg
        mem = self.encode(params, batch["frames"])
        x = self._dec_embed(params, batch["tokens"])

        def body(p, h):
            h = h + blocks.attn_apply(p["attn"], apply_norm(p["ln1"], h), cfg,
                                      causal=True)
            h = h + cross_attn_apply(p["xattn"], apply_norm(p["lnx"], h), mem,
                                     cfg)
            h = h + mlp(p["mlp"], apply_norm(p["ln2"], h), cfg.act)
            return h
        h = scan_layers(params["dec_layers"], x, body, cfg)
        h = apply_norm(params["ln_f"], h)
        logits = h @ params["embed"].T
        loss, acc = xent(logits, batch["labels"])
        return loss, {"ce": loss, "aux": jnp.asarray(0.0, jnp.float32),
                      "acc": acc}

    # ---------------- serving ---------------- #
    def prefill(self, params, batch, cache_len=None):
        """Encode frames, run decoder prompt, build both caches (the self-
        attention cache is always padded to dec_len_cap; cache_len ignored)."""
        cfg = self.cfg
        mem = self.encode(params, batch["frames"])
        x = self._dec_embed(params, batch["tokens"])
        cap = cfg.dec_len_cap
        S = x.shape[1]

        def body(h, p):
            a, kc, vc = blocks.attn_prefill(p["attn"], apply_norm(p["ln1"], h),
                                            cfg)
            h = h + a
            xk, xv = _cross_kv(p["xattn"], mem, cfg)
            h = h + _cross_attend(p["xattn"], apply_norm(p["lnx"], h), xk, xv,
                                  cfg)
            h = h + mlp(p["mlp"], apply_norm(p["ln2"], h), cfg.act)
            pad = cap - kc.shape[2]
            kc = jnp.pad(kc, ((0, 0), (0, 0), (0, pad), (0, 0)))
            vc = jnp.pad(vc, ((0, 0), (0, 0), (0, pad), (0, 0)))
            return h, (kc, vc, xk, xv)
        h, (kcs, vcs, xks, xvs) = jax.lax.scan(body, x, params["dec_layers"])
        h = apply_norm(params["ln_f"], h[:, -1:])
        logits = h @ params["embed"].T
        return logits, {"k": kcs, "v": vcs, "xk": xks, "xv": xvs}

    def decode_step(self, params, cache, token, pos):
        cfg = self.cfg
        x = self._dec_embed(params, token, pos0=pos)

        def body(p, h, kc, vc, xk, xv):
            a, kc, vc = blocks.attn_decode(p["attn"], apply_norm(p["ln1"], h),
                                           kc, vc, pos, cfg)
            h = h + a
            h = h + _cross_attend(p["xattn"], apply_norm(p["lnx"], h), xk, xv,
                                  cfg)
            h = h + mlp(p["mlp"], apply_norm(p["ln2"], h), cfg.act)
            return h, kc, vc, xk, xv
        h, (kcs, vcs, xks, xvs) = scan_decode(
            params["dec_layers"],
            (cache["k"], cache["v"], cache["xk"], cache["xv"]), x, body)
        h = apply_norm(params["ln_f"], h)
        logits = h @ params["embed"].T
        return logits, {"k": kcs, "v": vcs, "xk": xks, "xv": xvs}

    # ---------------- specs ---------------- #
    def batch_spec(self, batch: int, seq: int):
        cfg = self.cfg
        dl = _dec_len(seq, cfg.dec_len_cap)
        return {
            "frames": jax.ShapeDtypeStruct((batch, seq, cfg.d_model), cfg.jdtype),
            "tokens": jax.ShapeDtypeStruct((batch, dl), jnp.int32),
            "labels": jax.ShapeDtypeStruct((batch, dl), jnp.int32),
        }

    def cache_spec(self, batch: int, seq: int):
        cfg = self.cfg
        L, G, hd = cfg.n_layers, cfg.groups, cfg.hd
        return {
            "k": jax.ShapeDtypeStruct((L, batch, G, cfg.dec_len_cap, hd), cfg.jdtype),
            "v": jax.ShapeDtypeStruct((L, batch, G, cfg.dec_len_cap, hd), cfg.jdtype),
            "xk": jax.ShapeDtypeStruct((L, batch, G, seq, hd), cfg.jdtype),
            "xv": jax.ShapeDtypeStruct((L, batch, G, seq, hd), cfg.jdtype),
        }
