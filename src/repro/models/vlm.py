"""VLM — llava-next-34b language backbone with anyres tiling stub.

The vision tower (SigLIP/CLIP ViT + projector) is a STUB per the
assignment: ``batch["image_embeds"]`` carries (B, S_img, d_model)
projected patch embeddings (anyres: base tile + 4 sub-tiles = 5 * 576 =
2880 tokens). The language model consumes [image ; text] interleaved and
the loss runs over text positions only — which is exactly how LLaVA-NeXT
trains its LM stage.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .dense import DenseLM
from .lm import xent
from .layers import apply_norm


class VLM(DenseLM):
    def embed_batch(self, params, batch):
        txt = params["embed"][batch["tokens"]]
        img = batch["image_embeds"].astype(txt.dtype)
        return jnp.concatenate([img, txt], axis=1)

    def loss(self, params, batch):
        x = self.embed_batch(params, batch)
        h, aux = self.backbone(params, x)
        h = apply_norm(params["ln_f"], h)
        S_img = batch["image_embeds"].shape[1]
        logits = self.logits(params, h[:, S_img:])      # text positions only
        loss, acc = xent(logits, batch["labels"])
        return loss, {"ce": loss, "aux": aux, "acc": acc}

    def batch_spec(self, batch: int, seq: int):
        cfg = self.cfg
        s_img = min(cfg.n_frontend_tokens, max(seq // 2, 1))
        s_txt = seq - s_img
        return {
            "tokens": jax.ShapeDtypeStruct((batch, s_txt), jnp.int32),
            "labels": jax.ShapeDtypeStruct((batch, s_txt), jnp.int32),
            "image_embeds": jax.ShapeDtypeStruct((batch, s_img, cfg.d_model),
                                                 cfg.jdtype),
        }
