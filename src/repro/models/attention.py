"""Attention for the model zoo — pure-JAX, chunked (flash-style) online
softmax so 32k prefill never materializes an (S, S) score matrix.

Layout convention (the "grouped-MHA" view used everywhere):

    q        (B, Sq, G, H, hd)   G = cfg.groups KV slots, H = heads/slot
    k, v     (B, Sk, G, hd)
    output   (B, Sq, G, H, hd)

G is the runtime KV-slot count (= model-axis size in production so the KV
cache shards on its own axis; = n_kv_heads on CPU). Published KV heads are
``jnp.repeat``-ed to G; published q-heads are zero-padded per KV group
(see config.ArchConfig docstring). All accumulation in fp32.

Three paths:
  * ``chunked_attention``  — train/prefill, causal or not, full attention.
    Nested scan over q-chunks x kv-chunks; peak live score block is
    (B, G, H, cq, ck).
  * ``swa_attention``      — sliding-window train/prefill. One scan over
    q-chunks, each attending a static (window + cq) KV slice => cost is
    O(S·W), which is what makes long_500k lowerable for dense archs.
  * ``decode_attention``   — single-token decode against a KV cache
    (optionally a rolling buffer for SWA).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

NEG = -1e30


def _online_update(carry, s, vj):
    """One flash-attention accumulator update.

    carry = (m, l, acc): (B,G,H,cq), (B,G,H,cq), (B,G,H,cq,hd)
    s:   (B,G,H,cq,ck) masked scores (NEG where disallowed)
    vj:  (B,ck,G,hd)
    """
    m, l, acc = carry
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    # guard: rows that are still fully masked keep m at NEG; exp(s-m) would
    # be exp(0)=1 for masked entries, so explicitly zero them.
    p = jnp.exp(s - m_new[..., None])
    p = jnp.where(s <= NEG / 2, 0.0, p)
    alpha = jnp.exp(m - m_new)
    l = l * alpha + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bghqk,bkgd->bghqd", p.astype(vj.dtype), vj,
                    preferred_element_type=jnp.float32)
    acc = acc * alpha[..., None] + pv
    return m_new, l, acc


def _finish(m, l, acc, dtype):
    out = jnp.where(l[..., None] > 0, acc / jnp.maximum(l, 1e-30)[..., None], 0.0)
    return out.astype(dtype)  # (B,G,H,cq,hd)


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> Tuple[jnp.ndarray, int]:
    n = x.shape[axis]
    pad = -n % mult
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        x = jnp.pad(x, widths)
    return x, n


def chunked_attention(
    q: jnp.ndarray,            # (B, Sq, G, H, hd)
    k: jnp.ndarray,            # (B, Sk, G, hd)
    v: jnp.ndarray,            # (B, Sk, G, hd)
    *,
    causal: bool = True,
    q_offset: int = 0,         # absolute position of q[0] (cross-chunk causal)
    chunk_q: int = 512,
    chunk_kv: int = 512,
) -> jnp.ndarray:
    B, Sq, G, H, hd = q.shape
    Sk = k.shape[1]
    cq, ck = min(chunk_q, Sq), min(chunk_kv, Sk)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32)).astype(q.dtype)

    q, Sq0 = _pad_to(q * scale, 1, cq)
    k, Sk0 = _pad_to(k, 1, ck)
    v, _ = _pad_to(v, 1, ck)
    nq, nk = q.shape[1] // cq, k.shape[1] // ck

    qc = jnp.moveaxis(q.reshape(B, nq, cq, G, H, hd), 1, 0)   # (nq,B,cq,G,H,hd)
    kc = jnp.moveaxis(k.reshape(B, nk, ck, G, hd), 1, 0)      # (nk,B,ck,G,hd)
    vc = jnp.moveaxis(v.reshape(B, nk, ck, G, hd), 1, 0)

    def q_chunk(_, qi_i):
        qi, i = qi_i                                           # (B,cq,G,H,hd)
        q_pos = q_offset + i * cq + jnp.arange(cq)             # (cq,)

        def kv_step(carry, kj_vj_j):
            kj, vj, j = kj_vj_j
            s = jnp.einsum("bqghd,bkgd->bghqk", qi, kj,
                           preferred_element_type=jnp.float32)
            k_pos = j * ck + jnp.arange(ck)
            ok = (k_pos[None, :] < Sk0) & (jnp.arange(cq)[:, None] + i * cq < Sq0)
            if causal:
                ok &= k_pos[None, :] <= q_pos[:, None]
            s = jnp.where(ok[None, None, None], s, NEG)
            return _online_update(carry, s, vj), None

        m0 = jnp.full((B, G, H, cq), NEG, jnp.float32)
        l0 = jnp.zeros((B, G, H, cq), jnp.float32)
        a0 = jnp.zeros((B, G, H, cq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (kc, vc, jnp.arange(nk)))
        out = _finish(m, l, acc, q.dtype)                      # (B,G,H,cq,hd)
        return None, jnp.moveaxis(out, 3, 1)                   # (B,cq,G,H,hd)

    _, outs = jax.lax.scan(q_chunk, None, (qc, jnp.arange(nq)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * cq, G, H, hd)
    return out[:, :Sq0]


def swa_attention(
    q: jnp.ndarray,            # (B, Sq, G, H, hd)
    k: jnp.ndarray,            # (B, Sk, G, hd)  (Sk == Sq for train/prefill)
    v: jnp.ndarray,
    *,
    window: int,
    q_offset: int = 0,
    chunk_q: int = 512,
) -> jnp.ndarray:
    """Causal sliding-window attention: position i attends (i-window, i].

    Each q chunk sees a static-length KV slice of (window + cq) — cost
    O(S * W) rather than O(S^2).
    """
    B, Sq, G, H, hd = q.shape
    Sk = k.shape[1]
    cq = min(chunk_q, Sq)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32)).astype(q.dtype)

    q, Sq0 = _pad_to(q * scale, 1, cq)
    nq = q.shape[1] // cq
    W = window
    # front-pad KV by W so slice [i*cq : i*cq + W + cq) covers (q_pos - W, q_pos]
    kp = jnp.pad(k, ((0, 0), (W, (nq * cq) - Sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (W, (nq * cq) - Sk), (0, 0), (0, 0)))
    qc = jnp.moveaxis(q.reshape(B, nq, cq, G, H, hd), 1, 0)

    def q_chunk(_, qi_i):
        qi, i = qi_i
        ks = jax.lax.dynamic_slice_in_dim(kp, i * cq, W + cq, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(vp, i * cq, W + cq, axis=1)
        s = jnp.einsum("bqghd,bkgd->bghqk", qi, ks,
                       preferred_element_type=jnp.float32)
        q_pos = q_offset + i * cq + jnp.arange(cq)             # (cq,)
        k_pos = q_offset + i * cq - W + jnp.arange(W + cq)     # (W+cq,)
        ok = ((k_pos[None, :] >= 0)
              & (k_pos[None, :] <= q_pos[:, None])
              & (q_pos[:, None] - k_pos[None, :] < W)
              & (jnp.arange(cq)[:, None] + i * cq < Sq0))
        s = jnp.where(ok[None, None, None], s, NEG)
        m = jnp.max(s, axis=-1)
        p = jnp.where(s <= NEG / 2, 0.0, jnp.exp(s - m[..., None]))
        l = jnp.sum(p, axis=-1)
        pv = jnp.einsum("bghqk,bkgd->bghqd", p.astype(vs.dtype), vs,
                        preferred_element_type=jnp.float32)
        out = _finish(m, l, pv, q.dtype)
        return None, jnp.moveaxis(out, 3, 1)

    _, outs = jax.lax.scan(q_chunk, None, (qc, jnp.arange(nq)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * cq, G, H, hd)
    return out[:, :Sq0]


def plain_attention(
    q: jnp.ndarray,            # (B, Sq, G, H, hd)
    k: jnp.ndarray,            # (B, Sk, G, hd)
    v: jnp.ndarray,
    *,
    causal: bool = False,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Unchunked attention — for short decoder/cross-attn sequences."""
    B, Sq, G, H, hd = q.shape
    Sk = k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32)).astype(q.dtype)
    s = jnp.einsum("bqghd,bkgd->bghqk", q * scale, k,
                   preferred_element_type=jnp.float32)
    if causal:
        q_pos = q_offset + jnp.arange(Sq)
        ok = jnp.arange(Sk)[None, :] <= q_pos[:, None]
        s = jnp.where(ok[None, None, None], s, NEG)
    m = jnp.max(s, axis=-1)
    p = jnp.where(s <= NEG / 2, 0.0, jnp.exp(s - m[..., None]))
    l = jnp.sum(p, axis=-1)
    pv = jnp.einsum("bghqk,bkgd->bghqd", p.astype(v.dtype), v,
                    preferred_element_type=jnp.float32)
    out = _finish(m, l, pv, q.dtype)
    return jnp.moveaxis(out, 3, 1)


def decode_attention(
    q: jnp.ndarray,            # (B, 1, G, H, hd)
    k_cache: jnp.ndarray,      # (B, G, S, hd)  (post-RoPE keys)
    v_cache: jnp.ndarray,      # (B, G, S, hd)
    n_valid: jnp.ndarray,      # scalar int — number of valid cache slots
) -> jnp.ndarray:
    """One-token decode. For rolling (SWA) caches the caller passes
    n_valid = min(pos+1, window); slot order is irrelevant because keys are
    stored post-RoPE."""
    B, _, G, H, hd = q.shape
    S = k_cache.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32)).astype(q.dtype)
    s = jnp.einsum("bqghd,bgkd->bghqk", q * scale, k_cache,
                   preferred_element_type=jnp.float32)       # (B,G,H,1,S)
    ok = jnp.arange(S)[None, None, None, None, :] < n_valid
    s = jnp.where(ok, s, NEG)
    m = jnp.max(s, axis=-1)
    p = jnp.where(s <= NEG / 2, 0.0, jnp.exp(s - m[..., None]))
    l = jnp.sum(p, axis=-1)
    pv = jnp.einsum("bghqk,bgkd->bghqd", p.astype(v_cache.dtype), v_cache,
                    preferred_element_type=jnp.float32)
    out = _finish(m, l, pv, q.dtype)
    return jnp.moveaxis(out, 3, 1)                            # (B,1,G,H,hd)
