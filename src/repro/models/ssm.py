"""Attention-free SSM LM — falcon-mamba-7b (mamba1 architecture).

Each layer: x + mamba(rmsnorm(x)). No KV cache: decode state is
(h (L,B,d_inner,d_state) fp32, conv (L,B,cw-1,d_inner)) — constant in
sequence length, which is why this arch runs long_500k.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import blocks
from .layers import norm_init, apply_norm, stacked_init
from .lm import BaseLM, maybe_remat, scan_decode, scan_layers


class MambaLM(BaseLM):
    def init_layers(self, key):
        def one(k):
            return {"ln": norm_init(self.cfg.d_model, self.cfg.jdtype,
                                    self.cfg.norm),
                    "mamba": blocks.mamba_init(k, self.cfg)}
        return stacked_init(one, key, self.cfg.n_layers)

    def backbone(self, params, x):
        def body(p, h):
            return h + blocks.mamba_apply(p["mamba"], apply_norm(p["ln"], h),
                                          self.cfg)
        h = scan_layers(params["layers"], x, body, self.cfg)
        return h, jnp.asarray(0.0, jnp.float32)

    def backbone_prefill(self, params, x, cache_len=None):
        def body(h, p):
            y, hs, cs = blocks.mamba_prefill(p["mamba"], apply_norm(p["ln"], h),
                                             self.cfg)
            return h + y, (hs, cs)
        body = maybe_remat(body, self.cfg)
        h, (hs, cs) = jax.lax.scan(body, x, params["layers"])
        return h, {"h": hs, "conv": cs}

    def backbone_decode(self, params, cache, x, pos):
        def body(p, h, hstate, cstate):
            y, hstate, cstate = blocks.mamba_decode(
                p["mamba"], apply_norm(p["ln"], h), hstate, cstate, self.cfg)
            return h + y, hstate, cstate
        h, (hs, cs) = scan_decode(params["layers"],
                                  (cache["h"], cache["conv"]), x, body)
        return h, {"h": hs, "conv": cs}

    def cache_spec(self, batch: int, seq: int):
        cfg = self.cfg
        d_in = cfg.ssm.expand * cfg.d_model
        return {
            "h": jax.ShapeDtypeStruct(
                (cfg.n_layers, batch, d_in, cfg.ssm.d_state), jnp.float32),
            "conv": jax.ShapeDtypeStruct(
                (cfg.n_layers, batch, cfg.ssm.d_conv - 1, d_in), cfg.jdtype),
        }

    def supports_long_context(self) -> bool:
        return True
