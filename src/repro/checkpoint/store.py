"""Versioned on-disk snapshot store: npz tensors + json metadata.

Layout (mirrors the paper's Zenodo deposit structure):
  <root>/<ontology>/<version>/<model>/embeddings.npz
  <root>/<ontology>/<version>/<model>/metadata.json   (PROV sidecar)
"""
from __future__ import annotations

import json
import re
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

_DIGIT_RUN = re.compile(r"(\d+)")


def version_sort_key(version: str) -> tuple:
    """Natural/date-aware version ordering key.

    Digit runs compare numerically, so '2024-10' sorts after '2024-9' and
    'v10' after 'v2' — plain lexicographic sort gets both wrong, which made
    ``latest_version`` serve a stale release.
    """
    return tuple(int(part) if part.isdigit() else part
                 for part in _DIGIT_RUN.split(version))


class SnapshotStore:
    def __init__(self, root: str | Path):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    # ------------------------------------------------------------------ #
    def _dir(self, ontology: str, version: str, model: str) -> Path:
        return self.root / ontology / version / model

    def save(
        self,
        ontology: str,
        version: str,
        model: str,
        arrays: Dict[str, np.ndarray],
        metadata: Dict[str, Any],
    ) -> Path:
        d = self._dir(ontology, version, model)
        d.mkdir(parents=True, exist_ok=True)
        np.savez_compressed(d / "embeddings.npz", **arrays)
        (d / "metadata.json").write_text(json.dumps(metadata, indent=2, sort_keys=True))
        return d

    def load(self, ontology: str, version: str, model: str) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
        d = self._dir(ontology, version, model)
        with np.load(d / "embeddings.npz", allow_pickle=False) as z:
            arrays = {k: z[k] for k in z.files}
        metadata = json.loads((d / "metadata.json").read_text())
        return arrays, metadata

    def exists(self, ontology: str, version: str, model: str) -> bool:
        return (self._dir(ontology, version, model) / "embeddings.npz").exists()

    # ------------------------------------------------------------------ #
    def versions(self, ontology: str) -> List[str]:
        d = self.root / ontology
        if not d.exists():
            return []
        return sorted((p.name for p in d.iterdir() if p.is_dir()),
                      key=version_sort_key)

    def models(self, ontology: str, version: str) -> List[str]:
        d = self.root / ontology / version
        if not d.exists():
            return []
        return sorted(p.name for p in d.iterdir() if (p / "embeddings.npz").exists())

    def latest_version(self, ontology: str) -> Optional[str]:
        vs = self.versions(ontology)
        return vs[-1] if vs else None

    def ontologies(self) -> List[str]:
        return sorted(p.name for p in self.root.iterdir() if p.is_dir())
