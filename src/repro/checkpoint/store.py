"""Versioned on-disk snapshot store: npz tensors + json metadata.

Layout (mirrors the paper's Zenodo deposit structure; the params/graph
sidecars are what make post-restart warm-starts possible — PR 3):
  <root>/<ontology>/<version>/<model>/embeddings.npz
  <root>/<ontology>/<version>/<model>/metadata.json     (PROV sidecar)
  <root>/<ontology>/<version>/<model>/params.npz        (full model params)
  <root>/<ontology>/<version>/<model>/params_vocab.json (row-name vocab)
  <root>/<ontology>/<version>/graph.npz + graph_terms.json  (parsed release)
"""
from __future__ import annotations

import json
import re
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

_DIGIT_RUN = re.compile(r"(\d+)")


def version_sort_key(version: str) -> tuple:
    """Natural/date-aware version ordering key.

    Digit runs compare numerically, so '2024-10' sorts after '2024-9' and
    'v10' after 'v2' — plain lexicographic sort gets both wrong, which made
    ``latest_version`` serve a stale release.
    """
    return tuple(int(part) if part.isdigit() else part
                 for part in _DIGIT_RUN.split(version))


class SnapshotStore:
    def __init__(self, root: str | Path):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    # ------------------------------------------------------------------ #
    def _dir(self, ontology: str, version: str, model: str) -> Path:
        return self.root / ontology / version / model

    def save(
        self,
        ontology: str,
        version: str,
        model: str,
        arrays: Dict[str, np.ndarray],
        metadata: Dict[str, Any],
    ) -> Path:
        d = self._dir(ontology, version, model)
        d.mkdir(parents=True, exist_ok=True)
        np.savez_compressed(d / "embeddings.npz", **arrays)
        (d / "metadata.json").write_text(json.dumps(metadata, indent=2, sort_keys=True))
        return d

    def load(self, ontology: str, version: str, model: str) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
        d = self._dir(ontology, version, model)
        with np.load(d / "embeddings.npz", allow_pickle=False) as z:
            arrays = {k: z[k] for k in z.files}
        metadata = json.loads((d / "metadata.json").read_text())
        return arrays, metadata

    def load_metadata(self, ontology: str, version: str, model: str) -> Dict[str, Any]:
        """The PROV/lineage sidecar alone — no tensor load (the gateway's
        ``lineage`` endpoint reads many models per call)."""
        d = self._dir(ontology, version, model)
        return json.loads((d / "metadata.json").read_text())

    def exists(self, ontology: str, version: str, model: str) -> bool:
        return (self._dir(ontology, version, model) / "embeddings.npz").exists()

    # ------------------- full-param snapshots (warm start) ------------- #
    def save_params(
        self,
        ontology: str,
        version: str,
        model: str,
        params: Dict[str, np.ndarray],
        vocab: Dict[str, List[str]],
    ) -> Path:
        """Persist the *full* param pytree (not just the served entity
        matrix) plus the row-name vocabulary for each table axis, so the
        next release can warm-start even after a process restart.

        ``vocab`` maps role -> names, e.g. {"entity": [...], "relation":
        [...]}; for rdf2vec "entity" is the walk-token vocabulary.
        """
        d = self._dir(ontology, version, model)
        d.mkdir(parents=True, exist_ok=True)
        np.savez_compressed(
            d / "params.npz",
            **{k: np.asarray(v) for k, v in params.items()})
        (d / "params_vocab.json").write_text(
            json.dumps({k: list(map(str, v)) for k, v in vocab.items()}))
        return d

    def load_params(
        self, ontology: str, version: str, model: str
    ) -> Tuple[Dict[str, np.ndarray], Dict[str, List[str]]]:
        d = self._dir(ontology, version, model)
        with np.load(d / "params.npz", allow_pickle=False) as z:
            params = {k: z[k] for k in z.files}
        vocab = json.loads((d / "params_vocab.json").read_text())
        return params, vocab

    def has_params(self, ontology: str, version: str, model: str) -> bool:
        d = self._dir(ontology, version, model)
        return (d / "params.npz").exists() and (d / "params_vocab.json").exists()

    # ----------------- parsed-release snapshots (deltas) --------------- #
    def save_graph(self, ontology: str, version: str, kg) -> Path:
        """Persist the parsed release at the version level so the next
        update can compute an exact ``GraphDelta`` without re-downloading
        (or keeping) the previous OBO file."""
        d = self.root / ontology / version
        d.mkdir(parents=True, exist_ok=True)
        np.savez_compressed(
            d / "graph.npz",
            entities=np.asarray(kg.entities, dtype=np.str_),
            relations=np.asarray(kg.relations, dtype=np.str_),
            triples=np.asarray(kg.triples, dtype=np.int64),
        )
        terms = [[m.identifier, m.label, m.namespace, bool(m.obsolete),
                  m.definition] for m in kg.terms.values()]
        (d / "graph_terms.json").write_text(json.dumps(terms))
        return d

    def load_graph(self, ontology: str, version: str):
        from ..ontology.graph import KnowledgeGraph, TermMeta

        d = self.root / ontology / version
        with np.load(d / "graph.npz", allow_pickle=False) as z:
            entities = [str(x) for x in z["entities"]]
            relations = [str(x) for x in z["relations"]]
            triples = np.asarray(z["triples"], dtype=np.int64)
        terms = {}
        for ident, label, ns, obsolete, definition in json.loads(
                (d / "graph_terms.json").read_text()):
            terms[ident] = TermMeta(ident, label, ns, bool(obsolete), definition)
        return KnowledgeGraph(entities, relations, triples, terms)

    def has_graph(self, ontology: str, version: str) -> bool:
        d = self.root / ontology / version
        return (d / "graph.npz").exists() and (d / "graph_terms.json").exists()

    # ------------------------------------------------------------------ #
    def versions(self, ontology: str) -> List[str]:
        d = self.root / ontology
        if not d.exists():
            return []
        return sorted((p.name for p in d.iterdir() if p.is_dir()),
                      key=version_sort_key)

    def models(self, ontology: str, version: str) -> List[str]:
        d = self.root / ontology / version
        if not d.exists():
            return []
        return sorted(p.name for p in d.iterdir() if (p / "embeddings.npz").exists())

    def latest_version(self, ontology: str) -> Optional[str]:
        vs = self.versions(ontology)
        return vs[-1] if vs else None

    def ontologies(self) -> List[str]:
        return sorted(p.name for p in self.root.iterdir() if p.is_dir())
