"""Versioned on-disk snapshot store: npz tensors + json metadata.

Layout (mirrors the paper's Zenodo deposit structure; the params/graph
sidecars are what make post-restart warm-starts possible — PR 3):
  <root>/<ontology>/<version>/<model>/embeddings.npz
  <root>/<ontology>/<version>/<model>/metadata.json     (PROV sidecar)
  <root>/<ontology>/<version>/<model>/table.f32         (raw serve layout)
  <root>/<ontology>/<version>/<model>/table.json        (raw header/vocab)
  <root>/<ontology>/<version>/<model>/params.npz        (full model params)
  <root>/<ontology>/<version>/<model>/params_vocab.json (row-name vocab)
  <root>/<ontology>/<version>/graph.npz + graph_terms.json  (parsed release)
  <root>/<ontology>/<version>/.published                (seal marker)

The raw layout is the *serve* format: little-endian float32 rows padded to
a 64-byte stride so every row starts on a cache-line boundary, followed by
the per-row L2 norms (float32), with ids/labels/geometry in the JSON
sidecar.  ``open_table`` maps it read-only with ``np.memmap``, so N worker
processes share one page-cache-resident copy.  ``embeddings.npz`` remains
the interchange/training format — compressed, self-describing, and the
only file older snapshots have.

Within a model directory the write order is table.f32 → table.json →
metadata.json (each via tmp + ``os.replace``): metadata.json is the
per-model completion marker a concurrent reader may trust.  The
version-level ``.published`` seal marks *all* models of a version complete,
so cross-process watchers never surface a half-published multi-model
version.
"""
from __future__ import annotations

import json
import os
import re
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

_DIGIT_RUN = re.compile(r"(\d+)")

RAW_TABLE = "table.f32"
RAW_HEADER = "table.json"
RAW_FORMAT = "biokg-raw-v1"
RAW_ALIGN = 64          # bytes; row stride rounds up to this
SEAL_MARKER = ".published"


def norm_label(s: str) -> str:
    """The paper's 'automatic normalization of case and whitespace' —
    canonical here so publish-time sidecars and the serving layer agree on
    one normalization (``core.serving`` imports this)."""
    return " ".join(s.strip().lower().split())


def _atomic_write_bytes(path: Path, payload: bytes) -> None:
    tmp = path.with_name(path.name + ".tmp")
    tmp.write_bytes(payload)
    os.replace(tmp, path)


def _atomic_write_text(path: Path, payload: str) -> None:
    tmp = path.with_name(path.name + ".tmp")
    tmp.write_text(payload)
    os.replace(tmp, path)


def _atomic_savez(path: Path, **arrays: np.ndarray) -> None:
    """``np.savez_compressed`` through the tmp+``os.replace`` idiom — a
    concurrent reader (another worker warm-starting, a peer computing a
    delta) must never see a half-written archive.  The tmp name keeps the
    ``.npz`` suffix so numpy doesn't append its own."""
    tmp = path.with_name(path.name + ".tmp.npz")
    np.savez_compressed(tmp, **arrays)
    os.replace(tmp, path)


def version_sort_key(version: str) -> tuple:
    """Natural/date-aware version ordering key.

    Digit runs compare numerically, so '2024-10' sorts after '2024-9' and
    'v10' after 'v2' — plain lexicographic sort gets both wrong, which made
    ``latest_version`` serve a stale release.
    """
    return tuple(int(part) if part.isdigit() else part
                 for part in _DIGIT_RUN.split(version))


class SnapshotStore:
    def __init__(self, root: str | Path):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    # ------------------------------------------------------------------ #
    def _dir(self, ontology: str, version: str, model: str) -> Path:
        return self.root / ontology / version / model

    def save(
        self,
        ontology: str,
        version: str,
        model: str,
        arrays: Dict[str, np.ndarray],
        metadata: Dict[str, Any],
    ) -> Path:
        d = self._dir(ontology, version, model)
        d.mkdir(parents=True, exist_ok=True)
        _atomic_savez(d / "embeddings.npz", **arrays)
        if {"embeddings", "entity_ids", "labels"} <= set(arrays):
            self.save_raw_table(
                ontology, version, model,
                arrays["entity_ids"], arrays["labels"], arrays["embeddings"])
        # metadata last: its presence marks the model dir complete
        _atomic_write_text(d / "metadata.json",
                           json.dumps(metadata, indent=2, sort_keys=True))
        return d

    def load(self, ontology: str, version: str, model: str) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
        d = self._dir(ontology, version, model)
        with np.load(d / "embeddings.npz", allow_pickle=False) as z:
            arrays = {k: z[k] for k in z.files}
        metadata = json.loads((d / "metadata.json").read_text())
        return arrays, metadata

    def load_metadata(self, ontology: str, version: str, model: str) -> Dict[str, Any]:
        """The PROV/lineage sidecar alone — no tensor load (the gateway's
        ``lineage`` endpoint reads many models per call)."""
        d = self._dir(ontology, version, model)
        return json.loads((d / "metadata.json").read_text())

    def exists(self, ontology: str, version: str, model: str) -> bool:
        return (self._dir(ontology, version, model) / "embeddings.npz").exists()

    # --------------------- raw mmap serve layout ----------------------- #
    def save_raw_table(
        self,
        ontology: str,
        version: str,
        model: str,
        entity_ids,
        labels,
        embeddings: np.ndarray,
    ) -> Path:
        """Write the zero-copy serve layout: ``table.f32`` holds the rows
        padded to a 64-byte stride followed by the per-row L2 norms, and
        ``table.json`` holds geometry + ids/labels.  Norms are computed
        here, once, in float32 — bit-identical to what ``EmbeddingIndex``
        used to compute at load time, so cosine results don't move."""
        d = self._dir(ontology, version, model)
        d.mkdir(parents=True, exist_ok=True)
        emb = np.ascontiguousarray(np.asarray(embeddings, dtype="<f4"))
        n, dim = emb.shape
        stride = (max(dim, 1) * 4 + RAW_ALIGN - 1) // RAW_ALIGN * RAW_ALIGN // 4
        buf = np.zeros((n, stride), dtype="<f4")
        buf[:, :dim] = emb
        norms = np.linalg.norm(emb, axis=1).astype("<f4")
        _atomic_write_bytes(d / RAW_TABLE, buf.tobytes() + norms.tobytes())
        header = {
            "format": RAW_FORMAT,
            "dtype": "<f4",
            "rows": int(n),
            "dim": int(dim),
            "stride_floats": int(stride),
            "align_bytes": RAW_ALIGN,
            "norms_offset_floats": int(n * stride),
            "ids": [str(x) for x in entity_ids],
            "labels": [str(x) for x in labels],
            # autocomplete sidecar: unique normalized labels, pre-sorted at
            # publish time so every worker's index load skips the O(n log n)
            # re-sort (at 100k labels, once per process per version)
            "sorted_labels": sorted({norm_label(str(x)) for x in labels}),
        }
        _atomic_write_text(d / RAW_HEADER, json.dumps(header))
        return d

    def open_table(
        self, ontology: str, version: str, model: str
    ) -> Tuple[np.ndarray, np.ndarray, Dict[str, Any]]:
        """Read-only ``np.memmap`` views over the raw layout: ``(table
        [rows, dim], norms [rows], header)``.  Both views share one
        underlying map (reachable via ``.base``), so the pages are shared
        with every other process serving the same snapshot and the map is
        released when the last view is garbage-collected — at which point
        the files can be unlinked."""
        d = self._dir(ontology, version, model)
        header = json.loads((d / RAW_HEADER).read_text())
        if header.get("format") != RAW_FORMAT:
            raise ValueError(
                f"unknown raw layout {header.get('format')!r} for "
                f"{ontology}/{version}/{model}")
        n, dim, stride = header["rows"], header["dim"], header["stride_floats"]
        mm = np.memmap(d / RAW_TABLE, dtype="<f4", mode="r")
        if mm.size < n * stride + n:
            raise ValueError(
                f"truncated raw table for {ontology}/{version}/{model}: "
                f"{mm.size} floats < {n * stride + n}")
        table = mm[: n * stride].reshape(n, stride)[:, :dim]
        norms = mm[n * stride: n * stride + n]
        return table, norms, header

    def has_raw(self, ontology: str, version: str, model: str) -> bool:
        d = self._dir(ontology, version, model)
        return (d / RAW_TABLE).exists() and (d / RAW_HEADER).exists()

    # -------------------------- seal markers --------------------------- #
    def seal(self, ontology: str, version: str,
             models: Optional[List[str]] = None) -> Path:
        """Mark a version fully published (all its models written).  The
        updater calls this after the per-model publish loop; cross-process
        watchers prefer sealed versions so they never adopt a version whose
        second model is still being written."""
        d = self.root / ontology / version
        d.mkdir(parents=True, exist_ok=True)
        payload = {"models": sorted(models if models is not None
                                    else self.models(ontology, version))}
        _atomic_write_text(d / SEAL_MARKER, json.dumps(payload))
        return d / SEAL_MARKER

    def is_sealed(self, ontology: str, version: str) -> bool:
        return (self.root / ontology / version / SEAL_MARKER).exists()

    def sealed_versions(self, ontology: str) -> List[str]:
        return [v for v in self.versions(ontology)
                if self.is_sealed(ontology, v)]

    # ------------------- full-param snapshots (warm start) ------------- #
    def save_params(
        self,
        ontology: str,
        version: str,
        model: str,
        params: Dict[str, np.ndarray],
        vocab: Dict[str, List[str]],
    ) -> Path:
        """Persist the *full* param pytree (not just the served entity
        matrix) plus the row-name vocabulary for each table axis, so the
        next release can warm-start even after a process restart.

        ``vocab`` maps role -> names, e.g. {"entity": [...], "relation":
        [...]}; for rdf2vec "entity" is the walk-token vocabulary.
        """
        d = self._dir(ontology, version, model)
        d.mkdir(parents=True, exist_ok=True)
        _atomic_savez(
            d / "params.npz",
            **{k: np.asarray(v) for k, v in params.items()})
        _atomic_write_text(
            d / "params_vocab.json",
            json.dumps({k: list(map(str, v)) for k, v in vocab.items()}))
        return d

    def load_params(
        self, ontology: str, version: str, model: str
    ) -> Tuple[Dict[str, np.ndarray], Dict[str, List[str]]]:
        d = self._dir(ontology, version, model)
        with np.load(d / "params.npz", allow_pickle=False) as z:
            params = {k: z[k] for k in z.files}
        vocab = json.loads((d / "params_vocab.json").read_text())
        return params, vocab

    def has_params(self, ontology: str, version: str, model: str) -> bool:
        d = self._dir(ontology, version, model)
        return (d / "params.npz").exists() and (d / "params_vocab.json").exists()

    # ------------------- cached eval metrics (compare) ----------------- #
    def save_eval(self, ontology: str, version: str, model: str,
                  payload: Dict[str, Any]) -> Path:
        """Cache one model's eval metrics next to its snapshot so repeat
        ``compare`` jobs are free — the metrics of a published (immutable)
        snapshot never change, so the cache needs no invalidation."""
        d = self._dir(ontology, version, model)
        d.mkdir(parents=True, exist_ok=True)
        _atomic_write_text(d / "eval.json",
                           json.dumps(payload, sort_keys=True))
        return d / "eval.json"

    def load_eval(self, ontology: str, version: str, model: str) -> Dict[str, Any]:
        d = self._dir(ontology, version, model)
        return json.loads((d / "eval.json").read_text())

    def has_eval(self, ontology: str, version: str, model: str) -> bool:
        return (self._dir(ontology, version, model) / "eval.json").exists()

    # ----------------- parsed-release snapshots (deltas) --------------- #
    def save_graph(self, ontology: str, version: str, kg) -> Path:
        """Persist the parsed release at the version level so the next
        update can compute an exact ``GraphDelta`` without re-downloading
        (or keeping) the previous OBO file."""
        d = self.root / ontology / version
        d.mkdir(parents=True, exist_ok=True)
        _atomic_savez(
            d / "graph.npz",
            entities=np.asarray(kg.entities, dtype=np.str_),
            relations=np.asarray(kg.relations, dtype=np.str_),
            triples=np.asarray(kg.triples, dtype=np.int64),
        )
        terms = [[m.identifier, m.label, m.namespace, bool(m.obsolete),
                  m.definition] for m in kg.terms.values()]
        _atomic_write_text(d / "graph_terms.json", json.dumps(terms))
        return d

    def load_graph(self, ontology: str, version: str):
        from ..ontology.graph import KnowledgeGraph, TermMeta

        d = self.root / ontology / version
        with np.load(d / "graph.npz", allow_pickle=False) as z:
            entities = [str(x) for x in z["entities"]]
            relations = [str(x) for x in z["relations"]]
            triples = np.asarray(z["triples"], dtype=np.int64)
        terms = {}
        for ident, label, ns, obsolete, definition in json.loads(
                (d / "graph_terms.json").read_text()):
            terms[ident] = TermMeta(ident, label, ns, bool(obsolete), definition)
        return KnowledgeGraph(entities, relations, triples, terms)

    def has_graph(self, ontology: str, version: str) -> bool:
        d = self.root / ontology / version
        return (d / "graph.npz").exists() and (d / "graph_terms.json").exists()

    # ------------------------------------------------------------------ #
    def versions(self, ontology: str) -> List[str]:
        d = self.root / ontology
        if not d.exists():
            return []
        return sorted((p.name for p in d.iterdir() if p.is_dir()),
                      key=version_sort_key)

    def models(self, ontology: str, version: str) -> List[str]:
        d = self.root / ontology / version
        if not d.exists():
            return []
        return sorted(p.name for p in d.iterdir() if (p / "embeddings.npz").exists())

    def latest_version(self, ontology: str) -> Optional[str]:
        vs = self.versions(ontology)
        return vs[-1] if vs else None

    def ontologies(self) -> List[str]:
        return sorted(p.name for p in self.root.iterdir() if p.is_dir())
