from .store import SnapshotStore

__all__ = ["SnapshotStore"]
