from .store import SnapshotStore, version_sort_key

__all__ = ["SnapshotStore", "version_sort_key"]
