from .adam import OPTIMIZERS, Optimizer, OptState, adagrad, adam, sgd
from .schedules import constant, inverse_sqrt, linear_warmup_cosine

__all__ = [
    "OPTIMIZERS", "Optimizer", "OptState", "adagrad", "adam", "sgd",
    "constant", "inverse_sqrt", "linear_warmup_cosine",
]
