"""Minimal optimizer library (pytree transforms, optax-style but local)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any], Tuple[Any, OptState]]


def adam(lr: float | Callable[[jnp.ndarray], jnp.ndarray] = 1e-3,
         b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    """Adam / AdamW. ``lr`` may be a float or a schedule fn(step)->lr."""

    def init(params):
        zeros = jax.tree.map(jnp.zeros_like, params)
        return OptState(jnp.zeros((), jnp.int32), zeros, jax.tree.map(jnp.zeros_like, params))

    def update(grads, state, params):
        step = state.step + 1
        lr_t = lr(step) if callable(lr) else lr
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
        mu_hat = jax.tree.map(lambda m: m / (1 - b1 ** step), mu)
        nu_hat = jax.tree.map(lambda v: v / (1 - b2 ** step), nu)
        def upd(p, m, v):
            d = m / (jnp.sqrt(v) + eps)
            if weight_decay:
                d = d + weight_decay * p
            return p - lr_t * d
        new_params = jax.tree.map(upd, params, mu_hat, nu_hat)
        return new_params, OptState(step, mu, nu)

    return Optimizer(init, update)


def adagrad(lr: float = 0.5, eps: float = 1e-10) -> Optimizer:
    """Adagrad — the classic choice for sparse embedding training."""

    def init(params):
        acc = jax.tree.map(jnp.zeros_like, params)
        return OptState(jnp.zeros((), jnp.int32), acc, acc)

    def update(grads, state, params):
        acc = jax.tree.map(lambda a, g: a + g * g, state.mu, grads)
        new_params = jax.tree.map(
            lambda p, g, a: p - lr * g / (jnp.sqrt(a) + eps), params, grads, acc
        )
        return new_params, OptState(state.step + 1, acc, acc)

    return Optimizer(init, update)


def sgd(lr: float = 0.01, momentum: float = 0.0) -> Optimizer:
    def init(params):
        zeros = jax.tree.map(jnp.zeros_like, params)
        return OptState(jnp.zeros((), jnp.int32), zeros, zeros)

    def update(grads, state, params):
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state.mu, grads)
        else:
            mu = grads
        new_params = jax.tree.map(lambda p, g: p - lr * g, params, mu)
        return new_params, OptState(state.step + 1, mu, state.nu)

    return Optimizer(init, update)


OPTIMIZERS = {"adam": adam, "adagrad": adagrad, "sgd": sgd}
