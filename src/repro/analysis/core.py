"""Core machinery for the repo-native invariant analyzer.

Nine PRs of serving-stack growth accreted architecture contracts that
nothing checked mechanically: lock-guarded scheduler/job state, the
tmp+``os.replace`` atomic-publish idiom, no-jax-before-fork in the
worker pool, and the typed wire schema with stable error codes.  This
package turns them into AST-checked invariants (stdlib ``ast`` only —
no new dependencies).

This module owns everything rule-independent:

* :class:`Finding` — one diagnostic, with a line-independent
  fingerprint so baselines survive unrelated edits;
* :class:`SourceModule` — a parsed file plus its suppression
  directives (``# bioan: ignore[RULE]`` per line,
  ``# bioan: ignore-file[RULE]`` per file, ``# bioan: module-scope[RULE]``
  to opt a module into a path-scoped rule);
* the checker registry (:func:`register`, :func:`all_checkers`);
* :func:`run_analysis` — scan paths, run checkers, apply suppressions
  and the committed baseline, return an :class:`AnalysisReport`;
* baseline load/write and JSON / human report rendering.

Checkers live in :mod:`repro.analysis.checkers` (BIO rules — the
serving-stack contracts) and :mod:`repro.analysis.generic` (GEN rules —
pyflakes-level hygiene).
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import io
import json
import re
import time
import tokenize
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = [
    "ALL_RULES", "AnalysisReport", "Checker", "Finding", "SourceModule",
    "all_checkers", "baseline_fingerprints", "load_baseline", "register",
    "render_human", "run_analysis", "write_baseline",
]

#: directive grammar: ``# bioan: ignore`` / ``# bioan: ignore[BIO001,GEN002]``
#: / ``# bioan: ignore-file[...]`` / ``# bioan: module-scope[BIO002]``
_DIRECTIVE_RE = re.compile(
    r"#\s*bioan:\s*(?P<verb>ignore-file|ignore|module-scope)"
    r"\s*(?:\[(?P<rules>[A-Za-z0-9_,\s]+)\])?")

#: sentinel rule set meaning "every rule"
ALL_RULES = frozenset({"*"})


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic emitted by a checker."""

    rule: str          #: e.g. "BIO001"
    path: str          #: path relative to the scan root, POSIX separators
    line: int          #: 1-based line of the offending node
    col: int           #: 0-based column
    message: str       #: human sentence stating the violated contract
    context: str = ""  #: enclosing "Class.method" qualname, if any

    @property
    def fingerprint(self) -> str:
        """Line-independent identity used by the baseline file: unrelated
        edits that shift line numbers must not un-grandfather a finding."""
        raw = "|".join((self.rule, self.path, self.context, self.message))
        return hashlib.sha1(raw.encode("utf-8")).hexdigest()[:16]

    def to_json(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d["fingerprint"] = self.fingerprint
        return d


class SourceModule:
    """One parsed Python file plus its comment directives."""

    def __init__(self, path: Path, rel: str, text: str):
        self.path = path
        self.rel = rel
        self.text = text
        self.lines = text.splitlines()
        self.tree: Optional[ast.Module] = None
        self.parse_error: Optional[SyntaxError] = None
        #: line -> comment text (from tokenize, so '#' inside strings
        #: never counts as a comment)
        self.comments: Dict[int, str] = {}
        #: line -> rule set suppressed on that line ({"*"} = all)
        self.line_ignores: Dict[int, Set[str]] = {}
        #: rules suppressed for the whole file
        self.file_ignores: Set[str] = set()
        #: rules this module opts into despite being outside their path
        #: scope (used by path-scoped checkers like BIO002/BIO005)
        self.scope_optins: Set[str] = set()
        try:
            self.tree = ast.parse(text, filename=str(path))
        except SyntaxError as e:
            self.parse_error = e
        self._scan_comments()

    def _scan_comments(self) -> None:
        try:
            tokens = tokenize.generate_tokens(io.StringIO(self.text).readline)
            for tok in tokens:
                if tok.type == tokenize.COMMENT:
                    self.comments[tok.start[0]] = tok.string
        except (tokenize.TokenError, IndentationError, SyntaxError):
            return
        for lineno, comment in self.comments.items():
            m = _DIRECTIVE_RE.search(comment)
            if not m:
                continue
            rules = m.group("rules")
            ruleset = (set(ALL_RULES) if rules is None
                       else {r.strip().upper() for r in rules.split(",")
                             if r.strip()})
            verb = m.group("verb")
            if verb == "ignore":
                self.line_ignores.setdefault(lineno, set()).update(ruleset)
            elif verb == "ignore-file":
                self.file_ignores.update(ruleset)
            else:  # module-scope
                self.scope_optins.update(ruleset)

    # ------------------------------------------------------------------ #
    def has_comment_near(self, start: int, end: int) -> bool:
        """True if any comment lands on lines [start, end] — BIO005's
        "a silent swallow needs a written justification" test."""
        return any(start <= ln <= end for ln in self.comments)

    def is_suppressed(self, finding: Finding) -> bool:
        for rules in (self.file_ignores,
                      self.line_ignores.get(finding.line, ())):
            if rules and ("*" in rules or finding.rule in {r for r in rules}):
                return True
        return False

    def in_scope(self, checker: "Checker") -> bool:
        """Path-scoped checkers run on modules whose relpath matches one
        of the checker's suffixes, or that opt in via module-scope."""
        if checker.path_scope is None:
            return True
        if checker.code in self.scope_optins:
            return True
        rel = self.rel
        return any(rel.endswith(sfx) for sfx in checker.path_scope)


class Checker:
    """Base class: subclass, set ``code``/``name``/``contract``, implement
    :meth:`check_module` (per-file rules) or :meth:`check_project`
    (cross-file rules — receives every scanned module at once)."""

    code: str = ""
    name: str = ""
    #: one-line statement of the architecture contract the rule encodes
    contract: str = ""
    #: relpath suffixes the rule applies to; None = every module.
    #: Modules outside the scope can opt in with
    #: ``# bioan: module-scope[CODE]``.
    path_scope: Optional[Tuple[str, ...]] = None
    #: project-level rules run once over all modules, not per file
    project_level: bool = False

    def check_module(self, mod: SourceModule) -> Iterable[Finding]:
        return ()

    def check_project(
            self, mods: Sequence[SourceModule]) -> Iterable[Finding]:
        return ()


_REGISTRY: Dict[str, Checker] = {}


def register(cls: type) -> type:
    """Class decorator: instantiate and add a checker to the registry."""
    inst = cls()
    if not inst.code:
        raise ValueError(f"checker {cls.__name__} has no code")
    if inst.code in _REGISTRY:
        raise ValueError(f"duplicate checker code {inst.code}")
    _REGISTRY[inst.code] = inst
    return cls


def all_checkers() -> Dict[str, Checker]:
    # importing the rule modules populates the registry on first use
    from . import checkers as _c       # noqa: F401
    from . import generic as _g        # noqa: F401
    return dict(sorted(_REGISTRY.items()))


# ---------------------------------------------------------------------- #
# scanning

def iter_python_files(paths: Sequence[Path], root: Path) -> List[Tuple[Path, str]]:
    """Expand files/directories into (path, relpath) pairs, sorted,
    skipping caches and hidden directories."""
    out: List[Tuple[Path, str]] = []
    seen: Set[Path] = set()

    def rel_of(p: Path) -> str:
        try:
            return p.resolve().relative_to(root.resolve()).as_posix()
        except ValueError:
            return p.as_posix()

    for base in paths:
        if base.is_file():
            if base.suffix == ".py" and base not in seen:
                seen.add(base)
                out.append((base, rel_of(base)))
            continue
        for p in sorted(base.rglob("*.py")):
            parts = p.relative_to(base).parts
            if any(part.startswith(".") or part == "__pycache__"
                   for part in parts[:-1]):
                continue
            if p not in seen:
                seen.add(p)
                out.append((p, rel_of(p)))
    return out


@dataclasses.dataclass
class AnalysisReport:
    """Everything one run produced, pre-split by suppression status."""

    root: str
    findings: List[Finding]              #: actionable (unsuppressed)
    suppressed: List[Finding]            #: silenced by inline directives
    baselined: List[Finding]             #: grandfathered by the baseline
    files: int
    rules: List[str]
    elapsed_s: float
    stale_baseline: List[str] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.findings

    def to_json(self) -> Dict[str, object]:
        counts: Dict[str, int] = {}
        for f in self.findings:
            counts[f.rule] = counts.get(f.rule, 0) + 1
        return {
            "version": 1,
            "root": self.root,
            "files": self.files,
            "rules": self.rules,
            "elapsed_s": round(self.elapsed_s, 3),
            "ok": self.ok,
            "counts": counts,
            "suppressed": len(self.suppressed),
            "baselined": len(self.baselined),
            "stale_baseline": list(self.stale_baseline),
            "findings": [f.to_json() for f in self.findings],
        }


# ---------------------------------------------------------------------- #
# baseline

def load_baseline(path: Path) -> List[Dict[str, object]]:
    data = json.loads(path.read_text())
    if not isinstance(data, dict) or data.get("version") != 1:
        raise ValueError(f"unrecognized baseline format in {path}")
    return list(data.get("findings", []))


def baseline_fingerprints(entries: Iterable[Dict[str, object]]) -> Set[str]:
    return {str(e["fingerprint"]) for e in entries if "fingerprint" in e}


def write_baseline(path: Path, findings: Sequence[Finding]) -> None:
    entries = [{
        "fingerprint": f.fingerprint,
        "rule": f.rule,
        "path": f.path,
        "context": f.context,
        "message": f.message,
    } for f in sorted(findings, key=lambda f: (f.path, f.rule, f.line))]
    payload = json.dumps({"version": 1, "findings": entries}, indent=2)
    path.write_text(payload + "\n")


# ---------------------------------------------------------------------- #
# the runner

def _selected(checkers: Dict[str, Checker],
              select: Optional[Sequence[str]]) -> List[Checker]:
    if not select:
        return list(checkers.values())
    wanted = [s.strip().upper() for s in select if s.strip()]
    picked = [c for code, c in checkers.items()
              if any(code == w or code.startswith(w) for w in wanted)]
    if not picked:
        raise ValueError(f"--select matched no rules: {', '.join(wanted)}")
    return picked


def run_analysis(
    paths: Sequence[Path],
    root: Optional[Path] = None,
    select: Optional[Sequence[str]] = None,
    baseline: Optional[Path] = None,
) -> AnalysisReport:
    """Scan ``paths``, run the selected checkers, and split raw findings
    into actionable / suppressed / baselined."""
    t0 = time.perf_counter()
    root = root or Path.cwd()
    checkers = _selected(all_checkers(), select)

    mods: List[SourceModule] = []
    raw: List[Finding] = []
    for path, rel in iter_python_files([Path(p) for p in paths], root):
        try:
            text = path.read_text(encoding="utf-8")
        except (OSError, UnicodeDecodeError) as e:
            raw.append(Finding("E001", rel, 1, 0, f"unreadable file: {e}"))
            continue
        mod = SourceModule(path, rel, text)
        if mod.parse_error is not None:
            e = mod.parse_error
            raw.append(Finding("E001", rel, e.lineno or 1, (e.offset or 1) - 1,
                               f"syntax error: {e.msg}"))
            continue
        mods.append(mod)

    by_rel = {m.rel: m for m in mods}
    for checker in checkers:
        if checker.project_level:
            scoped = [m for m in mods if m.in_scope(checker)]
            raw.extend(checker.check_project(scoped))
        else:
            for mod in mods:
                if mod.in_scope(checker):
                    raw.extend(checker.check_module(mod))

    baseline_fps: Set[str] = set()
    baseline_entries: List[Dict[str, object]] = []
    if baseline is not None and baseline.exists():
        baseline_entries = load_baseline(baseline)
        baseline_fps = baseline_fingerprints(baseline_entries)

    actionable: List[Finding] = []
    suppressed: List[Finding] = []
    baselined: List[Finding] = []
    for f in sorted(raw, key=lambda f: (f.path, f.line, f.col, f.rule)):
        mod = by_rel.get(f.path)
        if mod is not None and mod.is_suppressed(f):
            suppressed.append(f)
        elif f.fingerprint in baseline_fps:
            baselined.append(f)
        else:
            actionable.append(f)

    # a baseline entry no longer matched by any finding is stale — the
    # violation was fixed, so the grandfather entry should be dropped
    live = {f.fingerprint for f in baselined}
    stale = [str(e["fingerprint"]) for e in baseline_entries
             if str(e.get("fingerprint")) not in live]

    return AnalysisReport(
        root=str(root),
        findings=actionable,
        suppressed=suppressed,
        baselined=baselined,
        files=len(mods),
        rules=[c.code for c in checkers],
        elapsed_s=time.perf_counter() - t0,
        stale_baseline=stale,
    )


def render_human(report: AnalysisReport, verbose: bool = False) -> str:
    """The terminal report: one line per finding plus a summary tail."""
    out: List[str] = []
    for f in report.findings:
        ctx = f" [{f.context}]" if f.context else ""
        out.append(f"{f.path}:{f.line}:{f.col + 1}: {f.rule} {f.message}{ctx}")
    if verbose:
        for f in report.suppressed:
            out.append(f"{f.path}:{f.line}: {f.rule} suppressed inline")
        for f in report.baselined:
            out.append(f"{f.path}:{f.line}: {f.rule} baselined "
                       f"({f.fingerprint})")
    if report.stale_baseline:
        out.append(f"note: {len(report.stale_baseline)} stale baseline "
                   "entr{} (fixed findings) — regenerate with "
                   "--write-baseline".format(
                       "y" if len(report.stale_baseline) == 1 else "ies"))
    n = len(report.findings)
    out.append(
        f"{n} finding{'s' if n != 1 else ''} "
        f"({len(report.suppressed)} suppressed, "
        f"{len(report.baselined)} baselined) in {report.files} files, "
        f"{report.elapsed_s:.2f}s")
    return "\n".join(out)
