"""CLI for the invariant analyzer.

    python -m repro.analysis                 # scan src/, report, exit 0
    python -m repro.analysis --strict        # exit 1 on any finding
    python -m repro.analysis --select GEN    # generic-lint rules only
    python -m repro.analysis --json out.json # machine-readable report
    python -m repro.analysis --write-baseline  # grandfather what's left

Exit codes: 0 = clean (or non-strict), 1 = unsuppressed findings under
``--strict``, 2 = usage error.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .core import all_checkers, render_human, run_analysis, write_baseline

DEFAULT_BASELINE = "analysis_baseline.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-native invariant analyzer (BIO + GEN rules)")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/directories to scan (default: src/)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 if any unsuppressed finding remains")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule codes/prefixes "
                         "(e.g. BIO, GEN001)")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="write the full JSON report to this path")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline file (default: ./{DEFAULT_BASELINE} "
                         "when present)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore any baseline file")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current unsuppressed findings to the "
                         "baseline file and exit 0")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="also list suppressed/baselined findings")
    args = ap.parse_args(argv)

    if args.list_rules:
        for code, checker in all_checkers().items():
            scope = ("all modules" if checker.path_scope is None
                     else ", ".join(checker.path_scope))
            print(f"{code} {checker.name}\n    contract: "
                  f"{checker.contract}\n    scope: {scope}")
        return 0

    root = Path.cwd()
    paths = [Path(p) for p in (args.paths or [])]
    if not paths:
        default = root / "src"
        paths = [default if default.is_dir() else root]
    for p in paths:
        if not p.exists():
            print(f"error: no such path: {p}", file=sys.stderr)
            return 2

    baseline = None
    if not args.no_baseline:
        baseline = Path(args.baseline) if args.baseline \
            else root / DEFAULT_BASELINE

    select = args.select.split(",") if args.select else None
    try:
        report = run_analysis(paths, root=root, select=select,
                              baseline=baseline)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    if args.write_baseline:
        target = baseline or (root / DEFAULT_BASELINE)
        write_baseline(target, report.findings)
        print(f"baselined {len(report.findings)} finding(s) -> {target}")
        return 0

    if args.json_out:
        out = Path(args.json_out)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(report.to_json(), indent=2) + "\n")

    print(render_human(report, verbose=args.verbose))
    if args.strict and not report.ok:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
