"""BIO rules — the serving-stack architecture contracts, as AST checks.

Each rule encodes one invariant that earlier PRs established by
convention and review:

* BIO001 lock-discipline   — state guarded somewhere must be guarded
  everywhere (PR 2 scheduler, PR 7 cache, PR 9 jobs).
* BIO002 atomic-write      — snapshot/state files are published with
  the tmp+``os.replace`` idiom from ``checkpoint/store.py`` (PR 6).
* BIO003 fork-safety       — no jax usage in worker-pool parent code
  before ``os.fork`` (PR 6: imports are fork-safe, device ops are not).
* BIO004 wire-schema drift — route table, request/response dataclasses,
  ``_TYPES`` codec map and error-code status maps stay in lock-step
  (PR 4/5).
* BIO005 exception-swallow — a broad ``except`` that silently drops
  control flow (and with it a Ticket/Job resolution path) must carry a
  written justification (PR 2/9 exactly-once contracts).

Rules fire off *content* markers (a class owning a lock, a module
calling ``os.fork``, a module defining ``CODE_STATUS``/``_routes``)
wherever possible, so fixture snippets exercise them without
repo-specific paths.  BIO002/BIO005 are path-scoped to the persistence
and serving-stack modules; other modules opt in with
``# bioan: module-scope[BIO002]``.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .core import Checker, Finding, SourceModule, register

#: threading factories whose result makes ``self.X`` a lock attribute
_LOCK_FACTORIES = {"Lock", "RLock", "Condition", "Semaphore",
                   "BoundedSemaphore"}


def _call_name(func: ast.expr) -> str:
    """Dotted display name of a call target: ``os.replace``, ``open`` …"""
    parts: List[str] = []
    node = func
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    elif not parts:
        return ""
    return ".".join(reversed(parts))


def _const_str(node: ast.expr) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


# ====================================================================== #
# BIO001 — lock discipline
# ====================================================================== #

def _class_lock_names(cls: ast.ClassDef) -> Set[str]:
    """Attributes assigned a ``threading.Lock()``-family object anywhere
    in the class — owning one is what opts the class into BIO001."""
    names: Set[str] = set()
    for node in ast.walk(cls):
        if not (isinstance(node, ast.Assign)
                and isinstance(node.value, ast.Call)):
            continue
        if _call_name(node.value.func).split(".")[-1] not in _LOCK_FACTORIES:
            continue
        for t in node.targets:
            if (isinstance(t, ast.Attribute)
                    and isinstance(t.value, ast.Name)
                    and t.value.id == "self"):
                names.add(t.attr)
    return names


def _store_sites(target: ast.expr) -> List[Tuple[str, str, ast.expr]]:
    """(attr, base_display, node) for attribute/subscript-store targets:
    ``self.x``, ``self.x[k]``, ``job.x``, ``job.x[k]`` …"""
    out: List[Tuple[str, str, ast.expr]] = []
    if isinstance(target, (ast.Tuple, ast.List)):
        for el in target.elts:
            out.extend(_store_sites(el))
        return out
    node = target
    if isinstance(node, ast.Subscript):
        node = node.value
    if isinstance(node, ast.Attribute):
        base = node.value
        if isinstance(base, ast.Name):
            out.append((node.attr, base.id, target))
        elif (isinstance(base, ast.Attribute)
              and isinstance(base.value, ast.Name)):
            out.append((node.attr, f"{base.value.id}.{base.attr}", target))
    return out


class _Site:
    __slots__ = ("line", "col", "base", "func")

    def __init__(self, line: int, col: int, base: str, func: str):
        self.line, self.col, self.base, self.func = line, col, base, func


@register
class LockDisciplineChecker(Checker):
    code = "BIO001"
    name = "lock-discipline"
    contract = ("in a class owning a threading lock, an attribute written "
                "under 'with self._lock' anywhere must be written under it "
                "everywhere (helpers called with the lock held are named "
                "'*_locked')")

    def check_module(self, mod: SourceModule) -> Iterable[Finding]:
        findings: List[Finding] = []
        assert mod.tree is not None
        for cls in [n for n in ast.walk(mod.tree)
                    if isinstance(n, ast.ClassDef)]:
            locks = _class_lock_names(cls)
            if not locks:
                continue
            guarded: Dict[str, List[_Site]] = {}
            unguarded: Dict[str, List[_Site]] = {}

            def record(stmt_targets, node, is_guarded, fn_name):
                for target in stmt_targets:
                    for attr, base, tnode in _store_sites(target):
                        if attr in locks:
                            continue
                        bucket = guarded if is_guarded else unguarded
                        bucket.setdefault(attr, []).append(_Site(
                            tnode.lineno, tnode.col_offset, base, fn_name))

            def walk(node, is_guarded, fn_name):
                if isinstance(node, (ast.With, ast.AsyncWith)):
                    g = is_guarded or any(
                        isinstance(it.context_expr, ast.Attribute)
                        and isinstance(it.context_expr.value, ast.Name)
                        and it.context_expr.value.id == "self"
                        and it.context_expr.attr in locks
                        for it in node.items)
                    for child in node.body:
                        walk(child, g, fn_name)
                    return
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    # a closure defined while holding the lock does not run
                    # while holding it — reset the guard state inside
                    for child in node.body:
                        walk(child, False, fn_name)
                    return
                if isinstance(node, ast.Assign):
                    record(node.targets, node, is_guarded, fn_name)
                elif isinstance(node, ast.AugAssign):
                    record([node.target], node, is_guarded, fn_name)
                elif isinstance(node, ast.AnnAssign) and node.value is not None:
                    record([node.target], node, is_guarded, fn_name)
                for child in ast.iter_child_nodes(node):
                    walk(child, is_guarded, fn_name)

            for fn in cls.body:
                if not isinstance(fn, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                    continue
                if fn.name == "__init__":
                    # construction is single-threaded by contract: writes
                    # there neither need the lock nor count as precedent
                    continue
                # repo convention (ResultCache._evict_locked): a '*_locked'
                # suffix documents "caller holds the lock"
                held = fn.name.endswith("_locked")
                for child in fn.body:
                    walk(child, held, fn.name)

            lockdisp = " / ".join(f"self.{l}" for l in sorted(locks))
            for attr, sites in sorted(unguarded.items()):
                if attr not in guarded:
                    continue
                for s in sites:
                    findings.append(Finding(
                        self.code, mod.rel, s.line, s.col,
                        f"'{s.base}.{attr}' is written without holding "
                        f"{lockdisp}, but other writes in class "
                        f"'{cls.name}' are lock-guarded — hold the lock, "
                        "or rename the helper '*_locked' if every caller "
                        "already holds it",
                        context=f"{cls.name}.{s.func}"))
        return findings


# ====================================================================== #
# BIO002 — atomic writes in persistence modules
# ====================================================================== #

#: direct write calls that publish bytes to a path
_WRITE_ATTR_CALLS = {"write_text", "write_bytes"}
_WRITE_DOTTED = {"np.save", "np.savez", "np.savez_compressed",
                 "numpy.save", "numpy.savez", "numpy.savez_compressed",
                 "json.dump", "pickle.dump"}


def _open_mode_writes(call: ast.Call) -> bool:
    """True for ``open(path, "w")`` / ``path.open("wb")`` etc."""
    mode: Optional[str] = None
    name = _call_name(call.func)
    if name == "open" and len(call.args) >= 2:
        mode = _const_str(call.args[1])
    elif name.endswith(".open") and call.args:
        mode = _const_str(call.args[0])
    for kw in call.keywords:
        if kw.arg == "mode":
            mode = _const_str(kw.value)
    if name != "open" and not name.endswith(".open"):
        return False
    if mode is None:
        return False
    return any(c in mode for c in "wax")


@register
class AtomicWriteChecker(Checker):
    code = "BIO002"
    name = "atomic-write"
    contract = ("files under the snapshot store / job state dirs are "
                "published tmp-first and made visible with os.replace "
                "(the checkpoint/store.py idiom); direct writes tear "
                "under concurrent readers and surviving processes")
    path_scope = (
        "repro/checkpoint/store.py",
        "repro/api/jobs.py",
        "repro/api/workers.py",
        "repro/core/registry.py",
        "repro/core/updater.py",
    )

    @staticmethod
    def _own_nodes(root: ast.AST) -> Iterable[ast.AST]:
        """Descendants of ``root`` excluding nested function bodies —
        each nested def gets its own atomic-idiom exemption decision."""
        stack = list(ast.iter_child_nodes(root))
        while stack:
            n = stack.pop()
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            yield n
            stack.extend(ast.iter_child_nodes(n))

    def check_module(self, mod: SourceModule) -> Iterable[Finding]:
        findings: List[Finding] = []
        assert mod.tree is not None
        # module level: no enclosing function can implement the idiom
        for stmt in mod.tree.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            for sub in ast.walk(stmt):
                if isinstance(sub, ast.Call):
                    self._check_call(sub, mod, "<module>", findings)
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._scan_function(node, findings, mod)
        return findings

    def _scan_function(self, fn, findings, mod) -> None:
        # the idiom itself is exempt: helpers named *atomic* and any
        # function that finishes its writes with an os.replace publish
        if "atomic" in fn.name:
            return
        own = list(self._own_nodes(fn))
        if any(isinstance(n, ast.Call)
               and _call_name(n.func) in ("os.replace", "os.rename")
               for n in own):
            return
        for n in own:
            if isinstance(n, ast.Call):
                self._check_call(n, mod, fn.name, findings)

    def _check_call(self, call: ast.Call, mod: SourceModule,
                    owner: str, findings: List[Finding]) -> None:
        name = _call_name(call.func)
        is_write = (
            name.split(".")[-1] in _WRITE_ATTR_CALLS
            or name in _WRITE_DOTTED
            or _open_mode_writes(call))
        if not is_write:
            return
        findings.append(Finding(
            self.code, mod.rel, call.lineno, call.col_offset,
            f"direct write '{name}' in function '{owner}' bypasses the "
            "tmp+os.replace atomic-publish idiom — write to a sibling "
            "tmp path and os.replace it (see checkpoint/store.py "
            "_atomic_write_bytes)",
            context=owner))


# ====================================================================== #
# BIO003 — fork safety in pre-fork parent code
# ====================================================================== #

@register
class ForkSafetyChecker(Checker):
    code = "BIO003"
    name = "fork-safety"
    contract = ("a module that calls os.fork keeps jax out of the parent "
                "image: no top-level jax imports and no jax usage in the "
                "fork-calling function or its class (importing inside "
                "worker/post-fork functions is fine — imports are "
                "fork-safe, the first device op is not)")

    _JAX_ROOTS = ("jax",)

    def check_module(self, mod: SourceModule) -> Iterable[Finding]:
        assert mod.tree is not None
        tree = mod.tree
        fork_fns = [
            fn for fn in ast.walk(tree)
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef))
            and any(isinstance(n, ast.Call)
                    and _call_name(n.func) in ("os.fork", "fork")
                    for n in ast.walk(fn))]
        module_forks = any(
            isinstance(n, ast.Call)
            and _call_name(n.func) in ("os.fork", "fork")
            for stmt in tree.body
            if not isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.ClassDef))
            for n in ast.walk(stmt))
        if not fork_fns and not module_forks:
            return ()

        findings: List[Finding] = []
        jax_names: Set[str] = set()
        # names bound to jax anywhere in the module (incl. deferred
        # imports — using them pre-fork is the hazard, not binding them)
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name.split(".")[0] in self._JAX_ROOTS:
                        jax_names.add(
                            (alias.asname or alias.name).split(".")[0])
            elif isinstance(node, ast.ImportFrom):
                if node.module and not node.level \
                        and node.module.split(".")[0] in self._JAX_ROOTS:
                    for alias in node.names:
                        jax_names.add(alias.asname or alias.name)

        # 1. top-level jax imports put jax in every parent's image
        for stmt in tree.body:
            bad = None
            if isinstance(stmt, ast.Import):
                bad = next((a.name for a in stmt.names
                            if a.name.split(".")[0] in self._JAX_ROOTS), None)
            elif isinstance(stmt, ast.ImportFrom) and stmt.module \
                    and not stmt.level \
                    and stmt.module.split(".")[0] in self._JAX_ROOTS:
                bad = stmt.module
            if bad is not None:
                findings.append(Finding(
                    self.code, mod.rel, stmt.lineno, stmt.col_offset,
                    f"top-level import of '{bad}' in a module that calls "
                    "os.fork — defer it into post-fork/worker functions "
                    "(the PR 6 pre-warm pattern imports modules, never "
                    "runs device ops, before forking)",
                    context="<module>"))

        if not jax_names:
            return findings

        # 2. jax usage in pre-fork zones: the fork-calling function, its
        # enclosing class (supervisor-side code), and the module body
        zones: List[Tuple[str, Iterable[ast.stmt]]] = []
        fork_classes = []
        for cls in [n for n in ast.walk(tree)
                    if isinstance(n, ast.ClassDef)]:
            if any(fn in ast.walk(cls) for fn in fork_fns):
                fork_classes.append(cls)
        for cls in fork_classes:
            zones.append((cls.name, cls.body))
        for fn in fork_fns:
            if not any(fn in ast.walk(cls) for cls in fork_classes):
                zones.append((fn.name, fn.body))
        zones.append(("<module>", [
            s for s in tree.body
            if not isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef, ast.Import,
                                  ast.ImportFrom))]))

        for zone_name, body in zones:
            for stmt in body:
                for n in ast.walk(stmt):
                    root = None
                    if isinstance(n, ast.Name) and n.id in jax_names \
                            and isinstance(n.ctx, ast.Load):
                        root = n.id
                    if root is not None:
                        findings.append(Finding(
                            self.code, mod.rel, n.lineno, n.col_offset,
                            f"'{root}' used in pre-fork parent code "
                            f"('{zone_name}') of a forking module — a "
                            "device op here initializes the jax backend "
                            "in the parent and corrupts every forked "
                            "worker; move it past os.fork",
                            context=zone_name))
        return findings


# ====================================================================== #
# BIO004 — wire-schema drift
# ====================================================================== #

def _dict_str_keys(node: ast.expr) -> List[Tuple[str, int, int]]:
    out = []
    if isinstance(node, ast.Dict):
        for k in node.keys:
            s = _const_str(k) if k is not None else None
            if s is not None:
                out.append((s, k.lineno, k.col_offset))
    return out


@register
class WireSchemaChecker(Checker):
    code = "BIO004"
    name = "wire-schema-drift"
    contract = ("the gateway route table, the schema dataclasses, the "
                "_TYPES wire-codec map, and the CODE_STATUS/_LEGACY error "
                "maps move in lock-step: every route has a registered "
                "request class + live handler, every Request/Response/Page "
                "dataclass round-trips through to_wire/from_wire, every "
                "error code raised anywhere has an HTTP status")
    project_level = True

    _WIRE_SUFFIXES = ("Request", "Response", "Page")

    def check_project(
            self, mods: Sequence[SourceModule]) -> Iterable[Finding]:
        findings: List[Finding] = []
        code_status: Dict[str, Tuple[SourceModule, int]] = {}
        legacy: Dict[str, Tuple[SourceModule, int]] = {}
        code_status_site: Optional[Tuple[SourceModule, int]] = None
        legacy_site: Optional[Tuple[SourceModule, int]] = None
        types_keys: Set[str] = set()
        types_site: Optional[Tuple[SourceModule, int]] = None
        dataclasses_by_mod: Dict[str, List[Tuple[str, SourceModule, int]]] = {}
        all_dataclasses: Set[str] = set()

        for mod in mods:
            assert mod.tree is not None
            for node in ast.walk(mod.tree):
                if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                        and isinstance(node.targets[0], ast.Name):
                    tname = node.targets[0].id
                elif isinstance(node, ast.AnnAssign) \
                        and isinstance(node.target, ast.Name) \
                        and node.value is not None:
                    tname = node.target.id
                else:
                    tname = None
                if tname == "CODE_STATUS":
                    val = node.value
                    code_status_site = (mod, node.lineno)
                    for key, ln, _ in _dict_str_keys(val):
                        code_status[key] = (mod, ln)
                elif tname == "_LEGACY":
                    legacy_site = (mod, node.lineno)
                    for key, ln, _ in _dict_str_keys(node.value):
                        legacy[key] = (mod, ln)
                elif tname == "_TYPES" and isinstance(node.value, ast.Dict):
                    types_site = (mod, node.lineno)
                    for k in node.value.keys:
                        if isinstance(k, ast.Name):
                            types_keys.add(k.id)
                if isinstance(node, ast.ClassDef):
                    if any("dataclass" in _call_name(
                            d.func if isinstance(d, ast.Call) else d)
                           for d in node.decorator_list):
                        dataclasses_by_mod.setdefault(mod.rel, []).append(
                            (node.name, mod, node.lineno))
                        all_dataclasses.add(node.name)

        # ---- error-code maps stay symmetric ---------------------------- #
        if code_status and legacy:
            for key, (mod, ln) in sorted(code_status.items()):
                if key not in legacy:
                    findings.append(Finding(
                        self.code, mod.rel, ln, 0,
                        f"error code '{key}' has an HTTP status in "
                        "CODE_STATUS but no legacy-exception mapping in "
                        "_LEGACY", context="CODE_STATUS"))
            for key, (mod, ln) in sorted(legacy.items()):
                if key not in code_status:
                    findings.append(Finding(
                        self.code, mod.rel, ln, 0,
                        f"error code '{key}' is mapped in _LEGACY but has "
                        "no HTTP status in CODE_STATUS — the HTTP layer "
                        "would crash serializing it", context="_LEGACY"))

        # ---- every wire dataclass is registered in the codec ----------- #
        if types_site is not None:
            types_mod = types_site[0]
            for name, mod, ln in dataclasses_by_mod.get(types_mod.rel, []):
                if name.endswith(self._WIRE_SUFFIXES) \
                        and name not in types_keys:
                    findings.append(Finding(
                        self.code, mod.rel, ln, 0,
                        f"wire dataclass '{name}' is not registered in "
                        "_TYPES — to_wire/from_wire cannot round-trip it",
                        context=name))

        # ---- the route table ------------------------------------------- #
        for mod in mods:
            assert mod.tree is not None
            for cls in [n for n in ast.walk(mod.tree)
                        if isinstance(n, ast.ClassDef)]:
                methods = {fn.name for fn in cls.body
                           if isinstance(fn, (ast.FunctionDef,
                                              ast.AsyncFunctionDef))}
                routes = self._route_entries(cls)
                for (rname, req_cls, handler, ln, col) in routes:
                    if req_cls is not None and all_dataclasses \
                            and req_cls not in all_dataclasses:
                        findings.append(Finding(
                            self.code, mod.rel, ln, col,
                            f"route '{rname}' references request class "
                            f"'{req_cls}' which is not a schema dataclass "
                            "in the scanned modules",
                            context=f"{cls.name}._routes"))
                    if req_cls is not None and types_site is not None \
                            and req_cls in all_dataclasses \
                            and req_cls not in types_keys:
                        findings.append(Finding(
                            self.code, mod.rel, ln, col,
                            f"route '{rname}' request class '{req_cls}' "
                            "is missing from the _TYPES codec map",
                            context=f"{cls.name}._routes"))
                    if handler is not None and handler not in methods:
                        findings.append(Finding(
                            self.code, mod.rel, ln, col,
                            f"route '{rname}' names handler "
                            f"'self.{handler}' but class '{cls.name}' "
                            "defines no such method",
                            context=f"{cls.name}._routes"))

        # ---- every raised error code has a status ---------------------- #
        if code_status:
            for mod in mods:
                assert mod.tree is not None
                for node in ast.walk(mod.tree):
                    if not isinstance(node, ast.Call):
                        continue
                    callee = _call_name(node.func)
                    code: Optional[str] = None
                    if callee.split(".")[-1] == "ApiError" and node.args:
                        code = _const_str(node.args[0])
                    elif callee.split(".")[-1] == "SchedulerError":
                        if len(node.args) >= 2:
                            code = _const_str(node.args[1])
                        for kw in node.keywords:
                            if kw.arg == "code":
                                code = _const_str(kw.value)
                    if code is not None and code not in code_status:
                        findings.append(Finding(
                            self.code, mod.rel, node.lineno,
                            node.col_offset,
                            f"error code '{code}' raised here has no "
                            "HTTP status in CODE_STATUS — add it to the "
                            "schema maps before using it",
                            context=callee))
        return findings

    @staticmethod
    def _route_entries(cls: ast.ClassDef):
        """Yield (name, request_class, handler_attr, line, col) from a
        ``self._routes = ( (...), ... )`` assignment."""
        out = []
        for node in ast.walk(cls):
            if not (isinstance(node, ast.Assign)
                    and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Attribute)
                    and node.targets[0].attr == "_routes"
                    and isinstance(node.targets[0].value, ast.Name)
                    and node.targets[0].value.id == "self"):
                continue
            if not isinstance(node.value, (ast.Tuple, ast.List)):
                continue
            for entry in node.value.elts:
                if not isinstance(entry, (ast.Tuple, ast.List)) \
                        or not entry.elts:
                    continue
                rname = _const_str(entry.elts[0]) or "<dynamic>"
                req_cls = None
                handler = None
                for el in entry.elts[1:]:
                    if isinstance(el, ast.Name) and req_cls is None:
                        req_cls = el.id
                    elif isinstance(el, ast.Attribute) \
                            and isinstance(el.value, ast.Name) \
                            and el.value.id == "self":
                        handler = el.attr
                out.append((rname, req_cls, handler,
                            entry.lineno, entry.col_offset))
        return out


# ====================================================================== #
# BIO005 — silent broad-exception swallows
# ====================================================================== #

@register
class ExceptionSwallowChecker(Checker):
    code = "BIO005"
    name = "exception-swallow"
    contract = ("a broad 'except' whose body only passes can drop a "
                "Ticket/Job resolution path on the floor; it must "
                "resolve, re-raise, narrow the type, or carry a comment "
                "stating why swallowing is safe")

    _BROAD = {"Exception", "BaseException"}

    def _is_broad(self, handler: ast.ExceptHandler) -> bool:
        t = handler.type
        if t is None:
            return True
        names: List[ast.expr] = list(t.elts) if isinstance(t, ast.Tuple) \
            else [t]
        for n in names:
            if isinstance(n, ast.Name) and n.id in self._BROAD:
                return True
            if isinstance(n, ast.Attribute) and n.attr in self._BROAD:
                return True
        return False

    def check_module(self, mod: SourceModule) -> Iterable[Finding]:
        findings: List[Finding] = []
        assert mod.tree is not None
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if not self._is_broad(node):
                continue
            if not all(isinstance(s, (ast.Pass, ast.Continue))
                       for s in node.body):
                continue
            end = max((s.end_lineno or s.lineno) for s in node.body)
            if mod.has_comment_near(node.lineno, end):
                continue
            what = "except" if node.type is None else \
                f"except {_call_name(node.type) or 'Exception'}"
            findings.append(Finding(
                self.code, mod.rel, node.lineno, node.col_offset,
                f"broad '{what}' silently swallows with no justification "
                "— resolve/re-raise/narrow it, or add a comment on the "
                "handler explaining why dropping this error is safe",
                context=""))
        return findings
