"""Repo-native invariant analyzer: AST checks for the serving-stack
architecture contracts (locking, atomic publish, fork safety, wire
schema, exception handling) plus pyflakes-level hygiene.

Run it with ``python -m repro.analysis [--strict] [paths...]``; see the
README's "Static analysis & invariants" section for the rule catalogue,
suppression syntax and baseline workflow.
"""
from .core import (AnalysisReport, Checker, Finding, SourceModule,
                   all_checkers, load_baseline, register, render_human,
                   run_analysis, write_baseline)

__all__ = [
    "AnalysisReport", "Checker", "Finding", "SourceModule", "all_checkers",
    "load_baseline", "register", "render_human", "run_analysis",
    "write_baseline",
]
