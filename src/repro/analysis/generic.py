"""GEN rules — pyflakes-level hygiene checks (no new dependencies).

* GEN001 unused-import       — a module-level import whose bound name is
  never referenced again (AST usage, ``__all__``, or string annotations).
* GEN002 fstring-no-placeholder — an f-string with no ``{...}`` fields
  is a plain string wearing a costume (usually a forgotten placeholder).

GEN001 is deliberately conservative: a name that appears as a word in
any string constant (docstring examples, string annotations) counts as
used, so it only fires when the import is provably dead.  ``__init__``
re-export modules are skipped entirely.
"""
from __future__ import annotations

import ast
import re
from typing import Iterable, List, Set

from .core import Checker, Finding, SourceModule, register


@register
class UnusedImportChecker(Checker):
    code = "GEN001"
    name = "unused-import"
    contract = ("module-level imports are either used or deleted; dead "
                "imports hide real dependencies and slow cold start")

    def check_module(self, mod: SourceModule) -> Iterable[Finding]:
        if mod.rel.endswith("__init__.py"):
            return ()
        assert mod.tree is not None
        tree = mod.tree

        used: Set[str] = set()
        exported: Set[str] = set()
        string_words: Set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.Name):
                used.add(node.id)
            elif isinstance(node, ast.Attribute):
                pass  # the root Name is already collected above
            elif isinstance(node, ast.Constant) \
                    and isinstance(node.value, str):
                string_words.update(re.findall(r"[A-Za-z_][A-Za-z0-9_]*",
                                               node.value))
            elif isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Name) and t.id == "__all__":
                        for el in getattr(node.value, "elts", []):
                            if isinstance(el, ast.Constant) \
                                    and isinstance(el.value, str):
                                exported.add(el.value)

        findings: List[Finding] = []
        for stmt in tree.body:
            if isinstance(stmt, ast.Import):
                for alias in stmt.names:
                    bound = alias.asname or alias.name.split(".")[0]
                    self._judge(bound, alias.name, stmt, mod, used,
                                exported, string_words, findings)
            elif isinstance(stmt, ast.ImportFrom):
                if stmt.module == "__future__":
                    continue
                for alias in stmt.names:
                    if alias.name == "*":
                        continue
                    bound = alias.asname or alias.name
                    self._judge(bound, alias.name, stmt, mod, used,
                                exported, string_words, findings)
        return findings

    def _judge(self, bound: str, imported: str, stmt: ast.stmt,
               mod: SourceModule, used: Set[str], exported: Set[str],
               string_words: Set[str], findings: List[Finding]) -> None:
        if bound.startswith("_"):
            return  # `import x as _x` marks a deliberate side-effect import
        if bound in exported or bound in string_words:
            return
        # the Name collector also saw the import statement's own binding?
        # no — import bindings are alias objects, not Name nodes, so any
        # Name occurrence is a real use
        if bound in used:
            return
        findings.append(Finding(
            self.code, mod.rel, stmt.lineno, stmt.col_offset,
            f"'{imported}' imported as '{bound}' is never used",
            context="<module>"))


@register
class FStringPlaceholderChecker(Checker):
    code = "GEN002"
    name = "fstring-no-placeholder"
    contract = ("an f-string must interpolate something; a placeholder-"
                "free f prefix usually means a brace was forgotten")

    def check_module(self, mod: SourceModule) -> Iterable[Finding]:
        assert mod.tree is not None
        # format_spec sub-f-strings (f"{x:>{w}}") are implementation
        # detail, not user-written f-strings — skip them
        spec_ids = set()
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.FormattedValue) \
                    and node.format_spec is not None:
                spec_ids.add(id(node.format_spec))
        findings: List[Finding] = []
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.JoinedStr) and id(node) not in spec_ids:
                if not any(isinstance(v, ast.FormattedValue)
                           for v in node.values):
                    findings.append(Finding(
                        self.code, mod.rel, node.lineno, node.col_offset,
                        "f-string without any placeholder — drop the 'f' "
                        "prefix or add the missing interpolation",
                        context=""))
        return findings
