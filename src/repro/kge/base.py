"""KGE model interface.

Every model maps integer (h, r, t) ids to a real-valued plausibility score —
**higher is more plausible** (distance models return negative distance).
Params are plain pytrees of jnp arrays so they shard with pjit unchanged.

The paper trains all models with PyKEEN defaults except dim=200 and
epochs=100; those two are the framework defaults here too.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, jnp.ndarray]

#: paper's fixed hyperparameters
PAPER_DIM = 200
PAPER_EPOCHS = 100


@dataclasses.dataclass(frozen=True)
class KGESpec:
    """Static model hyperparameters."""

    name: str
    n_entities: int
    n_relations: int
    dim: int = PAPER_DIM
    loss: str = "margin"      # margin | nssa | softplus | bce
    margin: float = 1.0
    p_norm: int = 1           # for translational models
    dtype: Any = jnp.float32


class KGEModel:
    """Base class. Subclasses override init / score (+ optionally the
    score_all_* fast paths and the post-step constraint)."""

    def __init__(self, spec: KGESpec):
        self.spec = spec

    # ------------------------------------------------------------------ #
    def init(self, key: jax.Array) -> Params:
        raise NotImplementedError

    def score(self, params: Params, h: jnp.ndarray, r: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
        """Elementwise score over broadcastable id arrays."""
        raise NotImplementedError

    # --- 1-vs-all fast paths (used by ranking eval & serving) ---------- #
    def score_all_tails(self, params: Params, h: jnp.ndarray, r: jnp.ndarray) -> jnp.ndarray:
        """(B,) ids -> (B, N) scores against every entity as tail."""
        n = self.spec.n_entities
        return self.score(params, h[:, None], r[:, None], jnp.arange(n)[None, :])

    def score_all_heads(self, params: Params, r: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
        n = self.spec.n_entities
        return self.score(params, jnp.arange(n)[None, :], r[:, None], t[:, None])

    # ------------------------------------------------------------------ #
    def constrain(self, params: Params) -> Params:
        """Post-step constraint (e.g. TransE unit-norm entities). Default: id."""
        return params

    def regularizer(self, params: Params, h: jnp.ndarray, r: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
        return jnp.asarray(0.0, self.spec.dtype)

    def entity_embeddings(self, params: Params) -> jnp.ndarray:
        """(N, dim) table that the serving layer snapshots and serves."""
        return params["entity"]

    # ------------------------------------------------------------------ #
    def param_roles(self) -> Dict[str, Optional[str]]:
        """Which vocabulary each param table's leading axis indexes.

        Returns {param_name: "entity" | "relation" | None}. The default
        infers the role from the leading dimension — every bundled model's
        tables are either entity-rowed (``entity``, ``bump``, rdf2vec's
        ``context``) or relation-rowed (``relation``, ``proj``, ``center``,
        ``width_raw``). Entity wins ties when n_entities == n_relations;
        override for models where that inference is wrong.
        """
        shapes = jax.eval_shape(self.init, jax.random.key(0))
        roles: Dict[str, Optional[str]] = {}
        for name, v in shapes.items():
            if v.shape and v.shape[0] == self.spec.n_entities:
                roles[name] = "entity"
            elif v.shape and v.shape[0] == self.spec.n_relations:
                roles[name] = "relation"
            else:
                roles[name] = None
        return roles

    # ------------------------------------------------------------------ #
    def param_shardings(self, mesh_axis: str = "model",
                        axis_size: Optional[int] = None) -> Params:
        """PartitionSpec pytree matching init(); entity/relation tables are
        vocab(row)-sharded over the model axis. Tables whose row count does
        not divide ``axis_size`` (e.g. the 3-row GO relation table on a
        16-way axis) are replicated."""
        from jax.sharding import PartitionSpec as P

        shapes = jax.eval_shape(self.init, jax.random.key(0))

        def spec_for(shape) -> P:
            if axis_size and shape[0] % axis_size != 0:
                return P(*([None] * len(shape)))
            return P(mesh_axis, *([None] * (len(shape) - 1)))

        return {k: spec_for(v.shape) for k, v in shapes.items()}


# ---------------------------------------------------------------------- #
_REGISTRY: Dict[str, Callable[[KGESpec], KGEModel]] = {}


def register(name: str) -> Callable:
    def deco(cls):
        _REGISTRY[name] = cls
        return cls
    return deco


def make_model(name: str, n_entities: int, n_relations: int, dim: int = PAPER_DIM,
               **kw) -> KGEModel:
    if name not in _REGISTRY:
        raise KeyError(f"unknown KGE model {name!r}; have {sorted(_REGISTRY)}")
    defaults = _MODEL_DEFAULTS.get(name, {})
    merged = {**defaults, **kw}
    spec = KGESpec(name=name, n_entities=n_entities, n_relations=n_relations,
                   dim=dim, **merged)
    return _REGISTRY[name](spec)


def available_models() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


#: per-model default losses (mirrors PyKEEN's per-model defaults)
_MODEL_DEFAULTS: Dict[str, Dict[str, Any]] = {
    "transe": dict(loss="margin", p_norm=1),
    "transr": dict(loss="margin", p_norm=2),
    "distmult": dict(loss="margin"),
    "hole": dict(loss="margin"),
    "boxe": dict(loss="nssa"),
    "rdf2vec": dict(loss="bce"),
}


def _uniform_init(key: jax.Array, shape: Tuple[int, ...], dim: int, dtype) -> jnp.ndarray:
    """PyKEEN/TransE-style xavier-uniform: U(-6/sqrt(d), 6/sqrt(d))."""
    bound = 6.0 / jnp.sqrt(jnp.asarray(dim, jnp.float32))
    return jax.random.uniform(key, shape, dtype, -bound, bound)


# ------------------------- warm-start helpers ------------------------- #
def vocab_remap(old_vocab, new_vocab) -> np.ndarray:
    """Row map from a new vocabulary onto an old one, matched by name.

    Returns an (len(new_vocab),) int32 array: ``map[i]`` is the old row of
    new item ``i``, or -1 if the item did not exist in the old vocabulary
    (fresh-initialize). Works for entity lists, relation lists, and
    rdf2vec walk-token vocabularies alike — anything addressed by string.
    """
    old_index = {name: i for i, name in enumerate(old_vocab)}
    return np.asarray([old_index.get(name, -1) for name in new_vocab],
                      dtype=np.int32)


def remap_params(
    model: "KGEModel",
    key: jax.Array,
    prev_params: Params,
    entity_map,
    relation_map,
) -> Tuple[Params, Dict[str, int]]:
    """Map a previous version's params onto ``model``'s index space.

    For each param table, rows whose vocabulary item survived the release
    (map >= 0) are carried over from ``prev_params``; rows for new items
    keep their fresh initialization; rows for removed items are dropped.
    Tables whose trailing shape changed (e.g. a dim change between
    versions) or that the previous checkpoint lacks fall back to fresh
    init wholesale — a silent architecture mismatch must not corrupt
    training.

    Returns (params, stats) with per-role carried/fresh row counts.
    """
    fresh = model.init(key)
    roles = model.param_roles()
    maps = {"entity": np.asarray(entity_map, dtype=np.int32),
            "relation": np.asarray(relation_map, dtype=np.int32)}
    out: Params = {}
    stats = {"entity_carried": int((maps["entity"] >= 0).sum()),
             "entity_fresh": int((maps["entity"] < 0).sum()),
             "relation_carried": int((maps["relation"] >= 0).sum()),
             "relation_fresh": int((maps["relation"] < 0).sum()),
             "tables_carried": 0, "tables_fresh": 0}
    for name, table in fresh.items():
        role = roles.get(name)
        prev = prev_params.get(name)
        if role is None or prev is None:
            out[name] = table
            stats["tables_fresh"] += 1
            continue
        prev = jnp.asarray(prev)
        mapping = maps[role]
        if (prev.ndim != table.ndim or prev.shape[1:] != table.shape[1:]
                or mapping.shape[0] != table.shape[0]):
            out[name] = table
            stats["tables_fresh"] += 1
            continue
        carried = prev[jnp.clip(jnp.asarray(mapping), 0, prev.shape[0] - 1)]
        keep = (jnp.asarray(mapping) >= 0).reshape(
            (-1,) + (1,) * (table.ndim - 1))
        out[name] = jnp.where(keep, carried.astype(table.dtype), table)
        stats["tables_carried"] += 1
    return out, stats
