"""KGE model interface.

Every model maps integer (h, r, t) ids to a real-valued plausibility score —
**higher is more plausible** (distance models return negative distance).
Params are plain pytrees of jnp arrays so they shard with pjit unchanged.

The paper trains all models with PyKEEN defaults except dim=200 and
epochs=100; those two are the framework defaults here too.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, jnp.ndarray]

#: paper's fixed hyperparameters
PAPER_DIM = 200
PAPER_EPOCHS = 100


@dataclasses.dataclass(frozen=True)
class KGESpec:
    """Static model hyperparameters."""

    name: str
    n_entities: int
    n_relations: int
    dim: int = PAPER_DIM
    loss: str = "margin"      # margin | nssa | softplus | bce
    margin: float = 1.0
    p_norm: int = 1           # for translational models
    dtype: Any = jnp.float32


class KGEModel:
    """Base class. Subclasses override init / score (+ optionally the
    score_all_* fast paths and the post-step constraint)."""

    def __init__(self, spec: KGESpec):
        self.spec = spec

    # ------------------------------------------------------------------ #
    def init(self, key: jax.Array) -> Params:
        raise NotImplementedError

    def score(self, params: Params, h: jnp.ndarray, r: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
        """Elementwise score over broadcastable id arrays."""
        raise NotImplementedError

    # --- 1-vs-all fast paths (used by ranking eval & serving) ---------- #
    def score_all_tails(self, params: Params, h: jnp.ndarray, r: jnp.ndarray) -> jnp.ndarray:
        """(B,) ids -> (B, N) scores against every entity as tail."""
        n = self.spec.n_entities
        return self.score(params, h[:, None], r[:, None], jnp.arange(n)[None, :])

    def score_all_heads(self, params: Params, r: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
        n = self.spec.n_entities
        return self.score(params, jnp.arange(n)[None, :], r[:, None], t[:, None])

    # ------------------------------------------------------------------ #
    def constrain(self, params: Params) -> Params:
        """Post-step constraint (e.g. TransE unit-norm entities). Default: id."""
        return params

    def regularizer(self, params: Params, h: jnp.ndarray, r: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
        return jnp.asarray(0.0, self.spec.dtype)

    def entity_embeddings(self, params: Params) -> jnp.ndarray:
        """(N, dim) table that the serving layer snapshots and serves."""
        return params["entity"]

    # ------------------------------------------------------------------ #
    def param_shardings(self, mesh_axis: str = "model",
                        axis_size: Optional[int] = None) -> Params:
        """PartitionSpec pytree matching init(); entity/relation tables are
        vocab(row)-sharded over the model axis. Tables whose row count does
        not divide ``axis_size`` (e.g. the 3-row GO relation table on a
        16-way axis) are replicated."""
        from jax.sharding import PartitionSpec as P

        shapes = jax.eval_shape(self.init, jax.random.key(0))

        def spec_for(shape) -> P:
            if axis_size and shape[0] % axis_size != 0:
                return P(*([None] * len(shape)))
            return P(mesh_axis, *([None] * (len(shape) - 1)))

        return {k: spec_for(v.shape) for k, v in shapes.items()}


# ---------------------------------------------------------------------- #
_REGISTRY: Dict[str, Callable[[KGESpec], KGEModel]] = {}


def register(name: str) -> Callable:
    def deco(cls):
        _REGISTRY[name] = cls
        return cls
    return deco


def make_model(name: str, n_entities: int, n_relations: int, dim: int = PAPER_DIM,
               **kw) -> KGEModel:
    if name not in _REGISTRY:
        raise KeyError(f"unknown KGE model {name!r}; have {sorted(_REGISTRY)}")
    defaults = _MODEL_DEFAULTS.get(name, {})
    merged = {**defaults, **kw}
    spec = KGESpec(name=name, n_entities=n_entities, n_relations=n_relations,
                   dim=dim, **merged)
    return _REGISTRY[name](spec)


def available_models() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


#: per-model default losses (mirrors PyKEEN's per-model defaults)
_MODEL_DEFAULTS: Dict[str, Dict[str, Any]] = {
    "transe": dict(loss="margin", p_norm=1),
    "transr": dict(loss="margin", p_norm=2),
    "distmult": dict(loss="margin"),
    "hole": dict(loss="margin"),
    "boxe": dict(loss="nssa"),
    "rdf2vec": dict(loss="bce"),
}


def _uniform_init(key: jax.Array, shape: Tuple[int, ...], dim: int, dtype) -> jnp.ndarray:
    """PyKEEN/TransE-style xavier-uniform: U(-6/sqrt(d), 6/sqrt(d))."""
    bound = 6.0 / jnp.sqrt(jnp.asarray(dim, jnp.float32))
    return jax.random.uniform(key, shape, dtype, -bound, bound)
