"""Negative sampling: uniform head/tail corruption (PyKEEN SLCWA default)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def corrupt(
    key: jax.Array,
    triples: jnp.ndarray,     # (B, 3) int
    n_entities: int,
    num_negs: int,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Return (h, r, t) of shape (B, K): each positive corrupted K times,
    half on the head side, half on the tail side (per-sample random choice).
    """
    b = triples.shape[0]
    k_rand, k_side = jax.random.split(key)
    rand_ents = jax.random.randint(k_rand, (b, num_negs), 0, n_entities)
    corrupt_head = jax.random.bernoulli(k_side, 0.5, (b, num_negs))
    h = jnp.where(corrupt_head, rand_ents, triples[:, 0:1])
    t = jnp.where(corrupt_head, triples[:, 2:3], rand_ents)
    r = jnp.broadcast_to(triples[:, 1:2], (b, num_negs))
    return h, r, t
