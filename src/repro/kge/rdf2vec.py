"""RDF2Vec (Ristoski & Paulheim, 2016) in JAX.

Two stages, as in the paper: (i) random-walk corpus over the KG
(``repro.data.walks`` — vectorized lax.scan walker), (ii) skip-gram with
negative sampling (word2vec SGNS) over the walk token sequences.

The model's vocabulary covers entities AND relation tokens; only the entity
rows are served. Exposed through the same KGEModel interface so the trainer,
registry and serving layer treat it uniformly — its "triples" are
(center, 0, context) pairs produced by the walker.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import KGEModel, Params, register


@register("rdf2vec")
class RDF2Vec(KGEModel):
    """SGNS: score(center, _, context) = <in_emb[center], out_emb[context]>.

    spec.n_entities must be the *token* vocabulary size (entities + relation
    tokens + pad); served embeddings are the first ``n_graph_entities`` rows
    of the input matrix (word2vec convention).
    """

    def init(self, key: jax.Array) -> Params:
        s = self.spec
        ki, ko = jax.random.split(key)
        scale = 1.0 / s.dim
        w_in = jax.random.uniform(ki, (s.n_entities, s.dim), s.dtype, -scale, scale)
        w_out = jnp.zeros((s.n_entities, s.dim), s.dtype)
        return {"entity": w_in, "context": w_out}

    def score(self, params: Params, h, r, t) -> jnp.ndarray:
        ce = params["entity"][h]
        xe = params["context"][t]
        ce, xe = jnp.broadcast_arrays(ce, xe)
        return jnp.sum(ce * xe, axis=-1)

    def score_all_tails(self, params: Params, h, r) -> jnp.ndarray:
        return params["entity"][h] @ params["context"].T

    def score_all_heads(self, params: Params, r, t) -> jnp.ndarray:
        return params["context"][t] @ params["entity"].T
