"""BoxE (Abboud et al., 2020), arity-2 specialization.

Entities: base point e + translational bump b. Relations: two boxes (one per
argument position), parameterized by center c and (positive) width w.
A point for position 1 is  p1 = e_h + b_t ; for position 2  p2 = e_t + b_h.
The distance function is the piecewise one from the paper (eq. 2-3):
inside the box, distance is scaled *down* by the width; outside, scaled up —
giving gradients that pull points into boxes.

score = -(dist(p1, box_r_1) + dist(p2, box_r_2))  (negative L2 of the
per-dimension distances, as in the paper with p=2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import KGEModel, Params, _uniform_init, register


def _box_dist(p: jnp.ndarray, center: jnp.ndarray, width: jnp.ndarray) -> jnp.ndarray:
    """Per-dimension BoxE distance, then L2 over dim.

    width is the half-width κ/2 >= 0 (softplus-parameterized by the caller).
    """
    w = width + 0.5  # paper's width+1 smoothing (here half-width + 0.5)
    low = center - width
    high = center + width
    inside = (p >= low) & (p <= high)
    d_in = jnp.abs(p - center) / w
    d_out = jnp.abs(p - center) * w - width * (w - 1.0 / w)
    per_dim = jnp.where(inside, d_in, d_out)
    return jnp.linalg.norm(per_dim, axis=-1)


@register("boxe")
class BoxE(KGEModel):
    def init(self, key: jax.Array) -> Params:
        s = self.spec
        ks = jax.random.split(key, 5)
        return {
            "entity": _uniform_init(ks[0], (s.n_entities, s.dim), s.dim, s.dtype),
            "bump": _uniform_init(ks[1], (s.n_entities, s.dim), s.dim, s.dtype),
            # two boxes per relation: centers + raw widths (softplus'd)
            "center": _uniform_init(ks[2], (s.n_relations, 2, s.dim), s.dim, s.dtype),
            "width_raw": 0.1 * jax.random.normal(ks[3], (s.n_relations, 2, s.dim), s.dtype),
        }

    def score(self, params: Params, h, r, t) -> jnp.ndarray:
        eh, bh = params["entity"][h], params["bump"][h]
        et, bt = params["entity"][t], params["bump"][t]
        c = params["center"][r]                       # (..., 2, d)
        w = jax.nn.softplus(params["width_raw"][r])   # (..., 2, d) > 0
        eh, bt = jnp.broadcast_arrays(eh, bt)
        et, bh = jnp.broadcast_arrays(et, bh)
        p1 = eh + bt
        p2 = et + bh
        d1 = _box_dist(p1, c[..., 0, :], w[..., 0, :])
        d2 = _box_dist(p2, c[..., 1, :], w[..., 1, :])
        return -(d1 + d2)
