"""TransR (Lin et al., 2015): project entities into a per-relation space.

score = -|| h W_r + r - t W_r ||_p  with W_r a (dim, dim) relation matrix.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import KGEModel, Params, _uniform_init, register


@register("transr")
class TransR(KGEModel):
    def init(self, key: jax.Array) -> Params:
        s = self.spec
        ke, kr, kw = jax.random.split(key, 3)
        ent = _uniform_init(ke, (s.n_entities, s.dim), s.dim, s.dtype)
        rel = _uniform_init(kr, (s.n_relations, s.dim), s.dim, s.dtype)
        # identity-ish init keeps early training close to TransE
        eye = jnp.eye(s.dim, dtype=s.dtype)
        noise = 0.01 * jax.random.normal(kw, (s.n_relations, s.dim, s.dim), s.dtype)
        return {"entity": ent, "relation": rel, "proj": eye[None] + noise}

    def _dist(self, x: jnp.ndarray) -> jnp.ndarray:
        if self.spec.p_norm == 1:
            return jnp.sum(jnp.abs(x), axis=-1)
        return jnp.sqrt(jnp.sum(x * x, axis=-1) + 1e-12)

    def _project(self, e: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
        """e (..., d), w (..., d, d) -> (..., d), with norm clip like PyKEEN."""
        p = jnp.einsum("...d,...de->...e", e, w)
        norm = jnp.linalg.norm(p, axis=-1, keepdims=True)
        return p / jnp.maximum(norm, 1.0)

    def score(self, params: Params, h, r, t) -> jnp.ndarray:
        he = params["entity"][h]
        te = params["entity"][t]
        re = params["relation"][r]
        w = params["proj"][r]
        hp = self._project(he, w)
        tp = self._project(te, w)
        return -self._dist(hp + re - tp)

    def score_all_tails(self, params: Params, h, r) -> jnp.ndarray:
        w = params["proj"][r]                                   # (B, d, d)
        hp = self._project(params["entity"][h], w)              # (B, d)
        # project every entity through each query's relation matrix
        allp = jnp.einsum("nd,bde->bne", params["entity"], w)   # (B, N, d)
        norm = jnp.linalg.norm(allp, axis=-1, keepdims=True)
        allp = allp / jnp.maximum(norm, 1.0)
        q = hp + params["relation"][r]                          # (B, d)
        return -self._dist(q[:, None, :] - allp)

    def score_all_heads(self, params: Params, r, t) -> jnp.ndarray:
        w = params["proj"][r]
        tp = self._project(params["entity"][t], w)
        allp = jnp.einsum("nd,bde->bne", params["entity"], w)
        norm = jnp.linalg.norm(allp, axis=-1, keepdims=True)
        allp = allp / jnp.maximum(norm, 1.0)
        q = tp - params["relation"][r]                          # h_p ≈ t_p - r
        return -self._dist(allp - q[:, None, :])

    def constrain(self, params: Params) -> Params:
        ent = params["entity"]
        norm = jnp.linalg.norm(ent, axis=-1, keepdims=True)
        return {**params, "entity": ent / jnp.maximum(norm, 1.0)}
