"""Losses for KGE training.

All take (pos_scores (B,), neg_scores (B, K)) with higher-is-better scores.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def margin_ranking(pos: jnp.ndarray, neg: jnp.ndarray, margin: float = 1.0) -> jnp.ndarray:
    """PyKEEN's default MarginRankingLoss (SLCWA)."""
    return jnp.mean(jax.nn.relu(margin + neg - pos[:, None]))


def nssa(pos: jnp.ndarray, neg: jnp.ndarray, margin: float = 9.0,
         adversarial_temperature: float = 1.0) -> jnp.ndarray:
    """Self-adversarial negative sampling (RotatE paper; PyKEEN default for BoxE)."""
    w = jax.nn.softmax(neg * adversarial_temperature, axis=-1)
    w = jax.lax.stop_gradient(w)
    neg_term = jnp.sum(w * jax.nn.softplus(margin + neg), axis=-1)
    pos_term = jax.nn.softplus(-(pos + margin))
    return jnp.mean(pos_term + neg_term)


def softplus_loss(pos: jnp.ndarray, neg: jnp.ndarray, **_) -> jnp.ndarray:
    return jnp.mean(jax.nn.softplus(-pos)) + jnp.mean(jax.nn.softplus(neg))


def bce(pos: jnp.ndarray, neg: jnp.ndarray, **_) -> jnp.ndarray:
    """Binary cross-entropy with logits (skip-gram w/ negative sampling form)."""
    pos_l = -jax.nn.log_sigmoid(pos)
    neg_l = -jax.nn.log_sigmoid(-neg)
    return jnp.mean(pos_l) + jnp.mean(jnp.sum(neg_l, axis=-1))


LOSSES = {
    "margin": margin_ranking,
    "nssa": nssa,
    "softplus": softplus_loss,
    "bce": bce,
}


def get_loss(name: str):
    return LOSSES[name]
