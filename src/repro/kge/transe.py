"""TransE (Bordes et al., 2013): score = -||h + r - t||_p."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import KGEModel, Params, _uniform_init, register


@register("transe")
class TransE(KGEModel):
    def init(self, key: jax.Array) -> Params:
        s = self.spec
        ke, kr = jax.random.split(key)
        ent = _uniform_init(ke, (s.n_entities, s.dim), s.dim, s.dtype)
        rel = _uniform_init(kr, (s.n_relations, s.dim), s.dim, s.dtype)
        rel = rel / (jnp.linalg.norm(rel, axis=-1, keepdims=True) + 1e-12)
        return {"entity": ent, "relation": rel}

    def _dist(self, x: jnp.ndarray) -> jnp.ndarray:
        if self.spec.p_norm == 1:
            return jnp.sum(jnp.abs(x), axis=-1)
        return jnp.sqrt(jnp.sum(x * x, axis=-1) + 1e-12)

    def score(self, params: Params, h, r, t) -> jnp.ndarray:
        he = params["entity"][h]
        re = params["relation"][r]
        te = params["entity"][t]
        return -self._dist(he + re - te)

    def score_all_tails(self, params: Params, h, r) -> jnp.ndarray:
        q = params["entity"][h] + params["relation"][r]       # (B, d)
        diff = q[:, None, :] - params["entity"][None, :, :]   # (B, N, d)
        return -self._dist(diff)

    def score_all_heads(self, params: Params, r, t) -> jnp.ndarray:
        # h + r - t = h - (t - r): distance between each entity and q
        q = params["entity"][t] - params["relation"][r]       # (B, d)
        diff = params["entity"][None, :, :] - q[:, None, :]
        return -self._dist(diff)

    def constrain(self, params: Params) -> Params:
        ent = params["entity"]
        norm = jnp.linalg.norm(ent, axis=-1, keepdims=True)
        return {**params, "entity": ent / jnp.maximum(norm, 1.0)}
