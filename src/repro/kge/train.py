"""Sharded KGE trainer.

One jit'd step: gather batch rows from the (possibly model-axis vocab-
sharded) tables, corrupt negatives, score with the model, apply the model's
loss, Adam/Adagrad update, post-step constraint. Under a mesh, the entity
table lives as P("model", None) and the batch as P("data"); XLA inserts the
gather/reduce-scatter collectives — no hand-written NCCL-style code.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..data.triples import TripleLoader
from ..optim import OPTIMIZERS, Optimizer
from .base import KGEModel, Params, remap_params
from .losses import get_loss
from .negatives import corrupt


@dataclasses.dataclass
class TrainConfig:
    batch_size: int = 1024
    num_negs: int = 32
    epochs: int = 100                  # paper default
    optimizer: str = "adam"
    lr: float = 1e-2
    reg_weight: float = 0.0
    seed: int = 0
    log_every: int = 50


def make_train_step(model: KGEModel, optimizer: Optimizer, cfg: TrainConfig):
    loss_fn = get_loss(model.spec.loss)
    loss_kwargs: Dict[str, Any] = {}
    if model.spec.loss in ("margin", "nssa"):
        loss_kwargs["margin"] = model.spec.margin

    def loss_of(params: Params, triples: jnp.ndarray, key: jax.Array):
        pos = model.score(params, triples[:, 0], triples[:, 1], triples[:, 2])
        nh, nr, nt = corrupt(key, triples, model.spec.n_entities, cfg.num_negs)
        neg = model.score(params, nh, nr, nt)
        loss = loss_fn(pos, neg, **loss_kwargs)
        if cfg.reg_weight:
            loss = loss + cfg.reg_weight * model.regularizer(
                params, triples[:, 0], triples[:, 1], triples[:, 2]
            )
        return loss

    def step(params: Params, opt_state, triples: jnp.ndarray, key: jax.Array):
        loss, grads = jax.value_and_grad(loss_of)(params, triples, key)
        params, opt_state = optimizer.update(grads, opt_state, params)
        params = model.constrain(params)
        return params, opt_state, loss

    return step, loss_of


class KGETrainer:
    """Drives the jit'd step over a TripleLoader; optionally mesh-sharded."""

    def __init__(self, model: KGEModel, cfg: TrainConfig, mesh: Optional[Mesh] = None):
        self.model = model
        self.cfg = cfg
        self.mesh = mesh
        self.optimizer = OPTIMIZERS[cfg.optimizer](cfg.lr)
        step, self._loss_of = make_train_step(model, self.optimizer, cfg)

        if mesh is not None:
            pspec = model.param_shardings("model", axis_size=mesh.shape.get("model"))
            param_sh = {k: NamedSharding(mesh, v) for k, v in pspec.items()}
            batch_sh = NamedSharding(mesh, P("data", None))
            rep = NamedSharding(mesh, P())
            self._step = jax.jit(
                step,
                in_shardings=(param_sh, None, batch_sh, rep),
                out_shardings=(param_sh, None, rep),
                donate_argnums=(0, 1),
            )
            self._param_sh = param_sh
        else:
            self._step = jax.jit(step, donate_argnums=(0, 1))
            self._param_sh = None

    def init(self, seed: Optional[int] = None) -> Tuple[Params, Any]:
        key = jax.random.key(self.cfg.seed if seed is None else seed)
        params = self.model.init(key)
        if self._param_sh is not None:
            params = jax.device_put(params, self._param_sh)
        return params, self.optimizer.init(params)

    def warm_init(
        self,
        prev_params: Params,
        entity_map: np.ndarray,
        relation_map: np.ndarray,
        seed: Optional[int] = None,
    ) -> Tuple[Params, Any, Dict[str, int]]:
        """Init from a previous version's params remapped onto this model's
        vocabulary (see :func:`repro.kge.base.remap_params`): surviving rows
        carried, new rows fresh, removed rows dropped. Optimizer state is
        fresh — the old moments index the old row space.

        Returns (params, opt_state, carry_stats).
        """
        key = jax.random.key(self.cfg.seed if seed is None else seed)
        params, stats = remap_params(self.model, key, prev_params,
                                     entity_map, relation_map)
        if self._param_sh is not None:
            params = jax.device_put(params, self._param_sh)
        return params, self.optimizer.init(params), stats

    def fit(
        self,
        triples: np.ndarray,
        params: Optional[Params] = None,
        opt_state: Any = None,
        epochs: Optional[int] = None,
        steps: Optional[int] = None,
        log: Optional[Callable[[Dict[str, Any]], None]] = None,
    ) -> Tuple[Params, Any, Dict[str, Any]]:
        """Train for ``epochs`` (paper default 100) or an explicit ``steps``."""
        cfg = self.cfg
        if params is None:
            params, opt_state = self.init()
        loader = TripleLoader(triples, cfg.batch_size, seed=cfg.seed)
        n_epochs = cfg.epochs if epochs is None else epochs
        total_steps = steps if steps is not None else n_epochs * max(1, loader.steps_per_epoch)

        key = jax.random.key(cfg.seed + 1)
        it = iter(loader)
        losses = []
        t0 = time.perf_counter()
        for i in range(total_steps):
            key, sub = jax.random.split(key)
            batch = jnp.asarray(next(it))
            params, opt_state, loss = self._step(params, opt_state, batch, sub)
            if i % cfg.log_every == 0 or i == total_steps - 1:
                l = float(loss)
                losses.append((i, l))
                if log:
                    log({"step": i, "loss": l})
        elapsed = time.perf_counter() - t0
        stats = {
            "steps": total_steps,
            "final_loss": losses[-1][1] if losses else float("nan"),
            "losses": losses,
            "wall_s": elapsed,
            "triples_per_s": total_steps * cfg.batch_size / max(elapsed, 1e-9),
        }
        return params, opt_state, stats
