from .base import (KGEModel, KGESpec, PAPER_DIM, PAPER_EPOCHS,
                   available_models, make_model, remap_params, vocab_remap)
from . import transe, transr, distmult, hole, boxe, rdf2vec  # noqa: F401 (registry)
from .eval import rank_based_eval
from .losses import LOSSES, get_loss
from .negatives import corrupt
from .train import KGETrainer, TrainConfig, make_train_step

__all__ = [
    "KGEModel", "KGESpec", "PAPER_DIM", "PAPER_EPOCHS",
    "available_models", "make_model", "remap_params", "vocab_remap",
    "rank_based_eval",
    "LOSSES", "get_loss", "corrupt",
    "KGETrainer", "TrainConfig", "make_train_step",
]
