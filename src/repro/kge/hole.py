"""HolE (Nickel et al., 2016): score = r . corr(h, t).

corr(h, t)[k] = sum_i h[i] * t[(i + k) mod d]  — circular correlation,
computed via rFFT: corr(h, t) = irfft(conj(rfft(h)) * rfft(t)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import KGEModel, Params, _uniform_init, register


def circular_correlation(h: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    d = h.shape[-1]
    fh = jnp.fft.rfft(h, n=d, axis=-1)
    ft = jnp.fft.rfft(t, n=d, axis=-1)
    return jnp.fft.irfft(jnp.conj(fh) * ft, n=d, axis=-1)


@register("hole")
class HolE(KGEModel):
    def init(self, key: jax.Array) -> Params:
        s = self.spec
        ke, kr = jax.random.split(key)
        ent = _uniform_init(ke, (s.n_entities, s.dim), s.dim, s.dtype)
        rel = _uniform_init(kr, (s.n_relations, s.dim), s.dim, s.dtype)
        return {"entity": ent, "relation": rel}

    def score(self, params: Params, h, r, t) -> jnp.ndarray:
        he = params["entity"][h]
        re = params["relation"][r]
        te = params["entity"][t]
        he, te = jnp.broadcast_arrays(he, te)
        return jnp.sum(re * circular_correlation(he, te), axis=-1)

    def score_all_tails(self, params: Params, h, r) -> jnp.ndarray:
        # <r, corr(h, t)> = <q, t> with q the circular convolution of h and r
        # (derivation in _tail_query) — turns 1-vs-all into a single matmul.
        he = params["entity"][h]                                 # (B, d)
        re = params["relation"][r]                               # (B, d)
        q = _tail_query(he, re)
        return q @ params["entity"].T

    def score_all_heads(self, params: Params, r, t) -> jnp.ndarray:
        te = params["entity"][t]
        re = params["relation"][r]
        q = _head_query(te, re)
        return q @ params["entity"].T


def _tail_query(h: jnp.ndarray, r: jnp.ndarray) -> jnp.ndarray:
    """q with <r, corr(h, t)> = <q, t> for all t.

    corr(h,t)_k = Σ_i h_i t_{(i+k) mod d}
    ⇒ score = Σ_k r_k Σ_i h_i t_{i+k} = Σ_j t_j Σ_i h_i r_{(j-i) mod d}
    ⇒ q = circular *convolution* of h and r = irfft(rfft(h)·rfft(r)).
    """
    d = h.shape[-1]
    return jnp.fft.irfft(jnp.fft.rfft(h, n=d, axis=-1) * jnp.fft.rfft(r, n=d, axis=-1), n=d, axis=-1)


def _head_query(t: jnp.ndarray, r: jnp.ndarray) -> jnp.ndarray:
    """q with <r, corr(h, t)> = <q, h> for all h.

    score = Σ_i h_i Σ_k r_k t_{(i+k) mod d} = <h, corr(r, t)>  (correlation of
    r with t) ⇒ q = irfft(conj(rfft(r))·rfft(t)).
    """
    d = t.shape[-1]
    return jnp.fft.irfft(jnp.conj(jnp.fft.rfft(r, n=d, axis=-1)) * jnp.fft.rfft(t, n=d, axis=-1), n=d, axis=-1)
