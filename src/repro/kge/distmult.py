"""DistMult (Yang et al., 2015): bilinear diagonal score = <h, r, t>."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import KGEModel, Params, _uniform_init, register


@register("distmult")
class DistMult(KGEModel):
    def init(self, key: jax.Array) -> Params:
        s = self.spec
        ke, kr = jax.random.split(key)
        ent = _uniform_init(ke, (s.n_entities, s.dim), s.dim, s.dtype)
        rel = _uniform_init(kr, (s.n_relations, s.dim), s.dim, s.dtype)
        return {"entity": ent, "relation": rel}

    def score(self, params: Params, h, r, t) -> jnp.ndarray:
        he = params["entity"][h]
        re = params["relation"][r]
        te = params["entity"][t]
        return jnp.sum(he * re * te, axis=-1)

    def score_all_tails(self, params: Params, h, r) -> jnp.ndarray:
        q = params["entity"][h] * params["relation"][r]         # (B, d)
        return q @ params["entity"].T                           # (B, N)

    def score_all_heads(self, params: Params, r, t) -> jnp.ndarray:
        q = params["entity"][t] * params["relation"][r]
        return q @ params["entity"].T

    def regularizer(self, params: Params, h, r, t) -> jnp.ndarray:
        # L2 on the touched rows only (sparse-friendly, like PyKEEN's LP reg)
        he = params["entity"][h]
        re = params["relation"][r]
        te = params["entity"][t]
        return jnp.mean(he**2) + jnp.mean(re**2) + jnp.mean(te**2)
