"""Filtered ranking evaluation: MRR, Hits@{1,3,10}.

Both-sides (head + tail corruption) evaluation against all entities, with
known true triples filtered out, matching PyKEEN's RankBasedEvaluator
(realistic/average rank for ties).
"""
from __future__ import annotations

from typing import Dict

import numpy as np
import jax.numpy as jnp

from .base import KGEModel, Params


def _ranks(scores: np.ndarray, true_idx: np.ndarray, filter_mask: np.ndarray) -> np.ndarray:
    """Realistic rank of true_idx in each row of scores, with filtering.

    filter_mask True = known-true competitor to exclude (score set to -inf).
    """
    b = scores.shape[0]
    true_scores = scores[np.arange(b), true_idx]
    scores = np.where(filter_mask, -np.inf, scores)
    scores[np.arange(b), true_idx] = true_scores
    greater = (scores > true_scores[:, None]).sum(axis=1)
    equal = (scores == true_scores[:, None]).sum(axis=1)  # includes self
    # realistic rank = mean of optimistic and pessimistic
    return greater + (equal + 1) / 2.0


def rank_based_eval(
    model: KGEModel,
    params: Params,
    eval_triples: np.ndarray,        # (M, 3)
    all_triples: np.ndarray,         # (T, 3) for filtering (train+valid+test)
    batch_size: int = 128,
    ks=(1, 3, 10),
) -> Dict[str, float]:
    n = model.spec.n_entities
    known_tails: Dict[tuple, set] = {}
    known_heads: Dict[tuple, set] = {}
    for h, r, t in all_triples:
        known_tails.setdefault((int(h), int(r)), set()).add(int(t))
        known_heads.setdefault((int(r), int(t)), set()).add(int(h))

    ranks = []
    m = eval_triples.shape[0]
    for start in range(0, m, batch_size):
        batch = eval_triples[start : start + batch_size]
        h, r, t = batch[:, 0], batch[:, 1], batch[:, 2]

        tail_scores = np.asarray(model.score_all_tails(params, jnp.asarray(h), jnp.asarray(r)))
        mask = np.zeros((batch.shape[0], n), dtype=bool)
        for i, (hh, rr) in enumerate(zip(h, r)):
            for tt in known_tails.get((int(hh), int(rr)), ()):
                mask[i, tt] = True
        ranks.append(_ranks(tail_scores, t, mask))

        head_scores = np.asarray(model.score_all_heads(params, jnp.asarray(r), jnp.asarray(t)))
        mask = np.zeros((batch.shape[0], n), dtype=bool)
        for i, (rr, tt) in enumerate(zip(r, t)):
            for hh in known_heads.get((int(rr), int(tt)), ()):
                mask[i, hh] = True
        ranks.append(_ranks(head_scores, h, mask))

    all_ranks = np.concatenate(ranks)
    out = {
        "mrr": float(np.mean(1.0 / all_ranks)),
        "mean_rank": float(np.mean(all_ranks)),
    }
    for k in ks:
        out[f"hits@{k}"] = float(np.mean(all_ranks <= k))
    return out
