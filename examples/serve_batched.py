"""End-to-end serving driver (the paper's deployment kind): stand up the
platform and push a batched request workload through it.

Trains snapshots for BOTH ontologies (GO-like and HP-like), then fires a
mixed stream of 300 requests across (ontology, model, endpoint) and reports
latency percentiles — single-query vs BatchScheduler (which groups
concurrent top-k queries into version-pinned micro-batches per
(ontology, model, version, k), the serving hot-spot optimization).

    PYTHONPATH=src python examples/serve_batched.py
"""
import sys
import tempfile
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.registry import EmbeddingRegistry
from repro.core.serving import BatchScheduler, ServingEngine, TopKRequest
from repro.core.updater import Updater
from repro.kge.train import TrainConfig
from repro.ontology.synthetic import GO_SPEC, HP_SPEC, generate


def main():
    rng = np.random.default_rng(0)
    with tempfile.TemporaryDirectory() as td:
        registry = EmbeddingRegistry(td)
        updater = Updater(registry, models=("transe", "distmult"), dim=100,
                          train_cfg=TrainConfig(batch_size=256, num_negs=8),
                          steps_override=60)
        graphs = {}
        for name, spec, n in (("go", GO_SPEC, 600), ("hp", HP_SPEC, 400)):
            kg = generate(spec, seed=1, n_terms=n)
            graphs[name] = kg

            class Ch:
                def __init__(self, name, kg):
                    self.name, self._kg = name, kg
                def latest(self):
                    return "2023-01-01", self._kg
            rep = updater.run_once(Ch(name, kg))
            print(f"[setup] {name}: trained {rep.trained_models} "
                  f"({kg.num_entities} classes) in {rep.wall_s:.1f}s")

        engine = ServingEngine(registry)

        # -------- workload: 300 mixed top-k requests -------- #
        reqs = []
        for _ in range(300):
            ont = rng.choice(["go", "hp"])
            mdl = rng.choice(["transe", "distmult"])
            q = graphs[ont].entities[int(rng.integers(
                0, graphs[ont].num_entities))]
            reqs.append(TopKRequest(ont, mdl, q, 10))

        # solo path
        t0 = time.perf_counter()
        lat = []
        for r in reqs:
            t1 = time.perf_counter()
            engine.closest_concepts(r.ontology, r.model, r.query, r.k)
            lat.append(time.perf_counter() - t1)
        t_solo = time.perf_counter() - t0
        lat = np.array(lat) * 1e3

        # batched path
        sched = BatchScheduler(engine, max_batch=64)
        t0 = time.perf_counter()
        tickets = [sched.submit(r) for r in reqs]
        results = sched.flush()
        t_batched = time.perf_counter() - t0

        assert len(results) == len(reqs) and not sched.errors
        print(f"\n[serve] solo:    {t_solo:.2f}s total, "
              f"p50={np.percentile(lat, 50):.2f}ms "
              f"p99={np.percentile(lat, 99):.2f}ms")
        print(f"[serve] batched: {t_batched:.2f}s total "
              f"({t_solo / t_batched:.1f}x) — version-pinned micro-batches "
              f"per (ontology, model, version, k): "
              f"{sched.stats['batches']} kernel calls, "
              f"{sched.stats['padded_queries']} pad queries")
        print(f"[serve] index cache: {engine.cache_stats()}")

        sample = results[tickets[0]]
        r0 = reqs[0]
        print(f"\nsample: top-3 for {r0.query} ({r0.ontology}/{r0.model})")
        for c in sample[:3]:
            print(f"  {c.score:+.4f} {c.identifier} {c.label[:40]}")
    print("\nOK")


if __name__ == "__main__":
    main()
