"""End-to-end concurrent serving driver (the paper's deployment kind):
stand up the platform and push a multi-client workload through the
future-based scheduler API.

Trains snapshots for BOTH ontologies (GO-like and HP-like), then fires a
mixed stream of 300 requests across (ontology, model, endpoint) two ways:

  * solo      — one `closest_concepts` call per request (no batching);
  * concurrent — four client threads, each submitting a burst of requests
    (``tickets = [scheduler.submit(r) for r in burst]``) and blocking on
    ``ticket.result()`` while the scheduler's background flush loop drains
    per-(ontology, model, version, k) queues under its deadline policy
    (``flush_after_ms`` or a full ``max_batch``, whichever first). No
    client ever calls ``flush()``; cross-client micro-batching is the
    speedup.

    PYTHONPATH=src python examples/serve_batched.py
"""
import sys
import tempfile
import threading
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.registry import EmbeddingRegistry
from repro.core.serving import BatchScheduler, ServingEngine, TopKRequest
from repro.core.updater import Updater
from repro.kge.train import TrainConfig
from repro.ontology.synthetic import GO_SPEC, HP_SPEC, generate

N_CLIENTS = 4
BURST = 8          # queries per client web request (a page of concepts)


def main():
    rng = np.random.default_rng(0)
    with tempfile.TemporaryDirectory() as td:
        registry = EmbeddingRegistry(td)
        updater = Updater(registry, models=("transe", "distmult"), dim=100,
                          train_cfg=TrainConfig(batch_size=256, num_negs=8),
                          steps_override=60)
        graphs = {}
        for name, spec, n in (("go", GO_SPEC, 600), ("hp", HP_SPEC, 400)):
            kg = generate(spec, seed=1, n_terms=n)
            graphs[name] = kg

            class Ch:
                def __init__(self, name, kg):
                    self.name, self._kg = name, kg
                def latest(self):
                    return "2023-01-01", self._kg
            rep = updater.run_once(Ch(name, kg))
            print(f"[setup] {name}: trained {rep.trained_models} "
                  f"({kg.num_entities} classes) in {rep.wall_s:.1f}s")

        engine = ServingEngine(registry)

        # -------- workload: 300 mixed top-k requests -------- #
        reqs = []
        for _ in range(300):
            ont = rng.choice(["go", "hp"])
            mdl = rng.choice(["transe", "distmult"])
            q = graphs[ont].entities[int(rng.integers(
                0, graphs[ont].num_entities))]
            reqs.append(TopKRequest(ont, mdl, q, 10))

        # solo path
        t0 = time.perf_counter()
        lat = []
        for r in reqs:
            t1 = time.perf_counter()
            engine.closest_concepts(r.ontology, r.model, r.query, r.k)
            lat.append(time.perf_counter() - t1)
        t_solo = time.perf_counter() - t0
        lat = np.array(lat) * 1e3

        # concurrent path: 4 clients firing bursts at the flush loop
        clat = []
        clat_lock = threading.Lock()
        first_ticket = {}

        def client(cid, my_reqs):
            mine = []
            for i in range(0, len(my_reqs), BURST):
                burst = my_reqs[i:i + BURST]
                t1 = time.perf_counter()
                tickets = [sched.submit(r) for r in burst]  # future Tickets
                if cid == 0 and not first_ticket:
                    first_ticket[0] = tickets[0]
                for t in tickets:
                    t.result(timeout=60)       # the loop resolves them
                dt = (time.perf_counter() - t1) / len(burst)
                mine.extend([dt] * len(burst))
            with clat_lock:
                clat.extend(mine)

        with BatchScheduler(engine, max_batch=64,
                            flush_after_ms=1.0) as sched:
            # warm every (table, padding-bucket) jit shape the workload can
            # hit, outside the timed region — retraces would dominate it
            for ont in ("go", "hp"):
                for mdl in ("transe", "distmult"):
                    b = 1
                    while b <= 32:
                        warm = [sched.submit(TopKRequest(
                            ont, mdl, graphs[ont].entities[i % 50], 10))
                            for i in range(b)]
                        for t in warm:
                            t.result(timeout=60)
                        b <<= 1
            warm_stats = dict(sched.stats)   # report only the timed region
            t0 = time.perf_counter()
            chunks = [reqs[i::N_CLIENTS] for i in range(N_CLIENTS)]
            workers = [threading.Thread(target=client, args=(i, c))
                       for i, c in enumerate(chunks)]
            for w in workers:
                w.start()
            for w in workers:
                w.join()
            t_conc = time.perf_counter() - t0
        assert len(clat) == len(reqs) and not sched.errors
        assert sched.stats["resolved"] == sched.stats["submitted"]
        clat = np.array(clat) * 1e3

        print(f"\n[serve] solo:       {t_solo:.2f}s total, "
              f"p50={np.percentile(lat, 50):.2f}ms "
              f"p99={np.percentile(lat, 99):.2f}ms")
        run_stats = {k: sched.stats[k] - warm_stats[k] for k in sched.stats}
        print(f"[serve] concurrent: {t_conc:.2f}s total "
              f"({t_solo / t_conc:.1f}x) — {N_CLIENTS} clients blocking on "
              f"ticket.result(), flush loop draining "
              f"(ontology, model, version, k) queues: "
              f"{run_stats['batches']} kernel calls "
              f"({run_stats['full_flushes']} full / "
              f"{run_stats['deadline_flushes']} deadline flushes), "
              f"p50={np.percentile(clat, 50):.2f}ms "
              f"p99={np.percentile(clat, 99):.2f}ms")
        print(f"[serve] index cache: {engine.cache_stats()}")

        sample_ticket = first_ticket[0]
        print(f"\nsample: top-3 from ticket {sample_ticket.id} "
              f"(version {sample_ticket.version})")
        for c in sample_ticket.result()[:3]:
            print(f"  {c.score:+.4f} {c.identifier} {c.label[:40]}")
    print("\nOK")


if __name__ == "__main__":
    main()
