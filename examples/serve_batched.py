"""End-to-end concurrent serving driver (the paper's deployment kind):
stand up the platform and push a multi-client workload through the
gateway API v1.

Trains snapshots for BOTH ontologies (GO-like and HP-like), then fires a
mixed stream of 300 closest-concepts requests three ways:

  * direct     — one deprecated ``engine.closest_concepts`` call per
    request: the pre-gateway serving mode, no cross-client batching;
  * concurrent — four client threads, each submitting a burst of
    requests per simulated web request
    (``gateway.closest_concepts_batch``: submit the wave, then collect)
    against a shared ``Gateway`` whose background flush loop drains
    per-(ontology, model, version, k) queues under its deadline policy
    (``flush_after_ms`` or a full ``max_batch``, whichever first). No
    client ever calls ``flush()``; cross-client micro-batching is the
    speedup;
  * async      — the same fan-out as coroutines:
    ``await AsyncGateway.closest_concepts_many(...)`` rides the
    loop-safe ticket bridge (PR 2's open async item, closed in PR 4).

Also demos the wire surface: ``gateway.handle(route, payload)`` for the
ops endpoints and a structured ApiError payload.

    PYTHONPATH=src python examples/serve_batched.py
"""
import asyncio
import sys
import tempfile
import threading
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.api import AsyncGateway, Gateway
from repro.api.schema import ClosestConceptsRequest
from repro.core.registry import EmbeddingRegistry
from repro.core.serving import ServingEngine
from repro.core.updater import Updater
from repro.kge.train import TrainConfig
from repro.ontology.synthetic import GO_SPEC, HP_SPEC, generate

N_CLIENTS = 4
BURST = 8          # queries per client web request (a page of concepts)


def main():
    rng = np.random.default_rng(0)
    with tempfile.TemporaryDirectory() as td:
        registry = EmbeddingRegistry(td)
        engine = ServingEngine(registry)
        updater = Updater(registry, engine=engine,
                          models=("transe", "distmult"), dim=100,
                          train_cfg=TrainConfig(batch_size=256, num_negs=8),
                          steps_override=60)
        graphs = {}
        for name, spec, n in (("go", GO_SPEC, 600), ("hp", HP_SPEC, 400)):
            kg = generate(spec, seed=1, n_terms=n)
            graphs[name] = kg

            class Ch:
                def __init__(self, name, kg):
                    self.name, self._kg = name, kg
                def latest(self):
                    return "2023-01-01", self._kg
            rep = updater.run_once(Ch(name, kg))
            print(f"[setup] {name}: trained {rep.trained_models} "
                  f"({kg.num_entities} classes) in {rep.wall_s:.1f}s")

        gw = Gateway(engine, max_batch=64, flush_after_ms=1.0)

        # the updater's invalidate flowed through the gateway hook: the
        # ops endpoints already see both publishes
        for ont in ("go", "hp"):
            v = gw.handle(f"/versions/{ont}")
            lin = gw.handle(f"/lineage/{ont}")
            print(f"[ops] {ont}: versions={v['versions']} "
                  f"models={v['models']} "
                  f"lineage[transe].mode={lin['lineage']['transe']['mode']}")

        # -------- workload: 300 mixed top-k requests -------- #
        reqs = []
        for _ in range(300):
            ont = rng.choice(["go", "hp"])
            mdl = rng.choice(["transe", "distmult"])
            q = graphs[ont].entities[int(rng.integers(
                0, graphs[ont].num_entities))]
            reqs.append(ClosestConceptsRequest(ont, mdl, q, 10))

        # warm every (table, padding-bucket) jit shape the workload can
        # hit — up to max_batch, the async gather can fill full buckets —
        # outside the timed regions: retraces would dominate them
        for ont in ("go", "hp"):
            for mdl in ("transe", "distmult"):
                b = 1
                while b <= 64:
                    gw.closest_concepts_batch(
                        [ClosestConceptsRequest(
                            ont, mdl, graphs[ont].entities[i % 50])
                         for i in range(b)])
                    b <<= 1
        warm_stats = dict(gw.scheduler.stats)  # report only the timed region

        # direct path: the deprecated per-call engine surface
        t0 = time.perf_counter()
        lat = []
        for r in reqs:
            t1 = time.perf_counter()
            engine.closest_concepts(r.ontology, r.model, r.query, r.k)
            lat.append(time.perf_counter() - t1)
        t_direct = time.perf_counter() - t0
        lat = np.array(lat) * 1e3

        # concurrent path: 4 threads calling the gateway against the loop
        clat = []
        clat_lock = threading.Lock()
        sample = {}

        def client(cid, my_reqs):
            mine = []
            for i in range(0, len(my_reqs), BURST):
                burst = my_reqs[i:i + BURST]
                t1 = time.perf_counter()
                resps = gw.closest_concepts_batch(burst)  # one wave
                if cid == 0 and not sample:
                    sample[0] = resps[0]
                dt = (time.perf_counter() - t1) / len(burst)
                mine.extend([dt] * len(burst))
            with clat_lock:
                clat.extend(mine)

        t0 = time.perf_counter()
        chunks = [reqs[i::N_CLIENTS] for i in range(N_CLIENTS)]
        workers = [threading.Thread(target=client, args=(i, c))
                   for i, c in enumerate(chunks)]
        for w in workers:
            w.start()
        for w in workers:
            w.join()
        t_conc = time.perf_counter() - t0
        assert len(clat) == len(reqs)
        clat = np.array(clat) * 1e3
        # snapshot NOW: the async run below shares the scheduler, and its
        # requests must not inflate the concurrent-mode batching report
        run_stats = {k: gw.scheduler.stats[k] - warm_stats[k]
                     for k in warm_stats}

        # async path: the same 300 requests as one gather fan-out
        ag = AsyncGateway(gw)

        async def async_run():
            return await ag.closest_concepts_many(reqs)

        t0 = time.perf_counter()
        ares = asyncio.run(async_run())
        t_async = time.perf_counter() - t0
        assert len(ares) == len(reqs)

        assert gw.scheduler.stats["resolved"] == gw.scheduler.stats["submitted"]

        print(f"\n[serve] direct:     {t_direct:.2f}s total, "
              f"p50={np.percentile(lat, 50):.2f}ms "
              f"p99={np.percentile(lat, 99):.2f}ms")
        print(f"[serve] concurrent: {t_conc:.2f}s total "
              f"({t_direct / t_conc:.1f}x) — {N_CLIENTS} clients bursting "
              f"closest_concepts_batch({BURST}), flush loop draining "
              f"(ontology, model, version, k) queues: "
              f"{run_stats['batches']} kernel calls "
              f"({run_stats['full_flushes']} full / "
              f"{run_stats['deadline_flushes']} deadline flushes), "
              f"p50={np.percentile(clat, 50):.2f}ms "
              f"p99={np.percentile(clat, 99):.2f}ms")
        print(f"[serve] async:      {t_async:.2f}s total "
              f"({t_direct / t_async:.1f}x) — one asyncio.gather over "
              f"{len(reqs)} awaitables")
        print(f"[serve] index cache: {engine.cache_stats()}")

        # -------- the wire surface, including a structured error -------- #
        err = gw.handle("/sim/go/transe", {"a": "BOGUS-1", "b": "BOGUS-2"})
        print(f"\n[wire] error payload: code={err['code']} "
              f"status={err['status']} missing={err['details']['missing']}")
        resp = sample[0]
        print(f"sample: top-3 for {resp.query} (version {resp.version})")
        for c in resp.results[:3]:
            print(f"  {c.score:+.4f} {c.identifier} {c.label[:40]}")
        gw.close()
    print("\nOK")


if __name__ == "__main__":
    main()
