"""Dynamic knowledge, incrementally: the delta-aware update pipeline.

Simulates a GO release channel evolving over four low-churn versions (a few
terms added, one or two obsoleted, a couple of edges rewired — like GO's
monthly releases). The updater polls a directory of OBO files; on checksum
change it *plans* the update: diff the new release against the persisted
parent graph (``GraphDelta``), then pick a mode — **full** retraining for
the first release (no parent) and **incremental** for every later one,
because the per-release entity churn stays below the threshold. Incremental
updates warm-start from the parent version's params (surviving entities
keep their trained vectors, new terms get fresh rows) at a fraction of the
full step budget, publish with lineage metadata, and land in the serving
engine through the same atomic latest-pointer invalidate. Unchanged polls
remain no-ops.

Then demonstrates the knowledge-evolution study the paper enables: tracking
a term's neighborhood drift across versions — now with warm-started
embeddings, the surviving neighborhood stays far more stable.

    PYTHONPATH=src python examples/dynamic_update.py
"""
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.registry import EmbeddingRegistry
from repro.core.serving import ServingEngine
from repro.core.updater import FileReleaseChannel, Updater, poll_loop
from repro.kge.train import TrainConfig
from repro.ontology import obo
from repro.ontology.synthetic import GO_SPEC, release_series


def main():
    series = release_series(GO_SPEC, n_versions=4, seed=0, n_terms=300,
                            add_frac=0.02, obsolete_frac=0.005,
                            rewire_frac=0.005)
    with tempfile.TemporaryDirectory() as td:
        releases = Path(td) / "releases"
        releases.mkdir()
        registry = EmbeddingRegistry(Path(td) / "registry")
        engine = ServingEngine(registry)
        updater = Updater(registry, engine=engine,
                          models=("transe", "distmult"), dim=64,
                          train_cfg=TrainConfig(batch_size=256, num_negs=8),
                          steps_override=200,
                          churn_threshold=0.25, warm_frac=0.25)
        channel = FileReleaseChannel("go", releases)

        track = series[0][1].entities[7]      # a class present from v1
        print(f"tracking neighborhood of {track} "
              f"({series[0][1].terms[track].label!r})\n")

        prev_top = None
        full_wall = None
        for round_idx, (tag, kg) in enumerate(series):
            # the "download" the cron job would do:
            obo.save_obo(kg, releases / f"{tag}.obo", header_version=tag)

            # poll twice: first sees the change, second is a no-op
            reports = poll_loop(updater, [channel], iterations=2,
                                base_seed=round_idx * 10)
            rep = reports[0]
            assert rep.changed and not reports[1].changed
            if rep.mode == "full":
                full_wall = rep.wall_s
                print(f"release {tag}: {kg.num_entities} classes -> FULL "
                      f"retrain of {rep.trained_models} in {rep.wall_s:.1f}s "
                      f"(no parent version)")
            else:
                churn = rep.delta["churn_fraction"]
                carried = rep.details["transe"]["carried_rows"]
                speed = full_wall / rep.wall_s if full_wall else float("nan")
                print(f"release {tag}: {kg.num_entities} classes -> "
                      f"INCREMENTAL from {rep.parent_version} "
                      f"(churn {churn:.1%}, {carried} vectors carried) in "
                      f"{rep.wall_s:.1f}s — {speed:.1f}x vs the full retrain")

            top = [c.identifier for c in
                   engine.closest_concepts("go", "transe", track, k=5)]
            if prev_top is not None:
                overlap = len(set(top) & set(prev_top))
                print(f"    top-5 neighbors: {top}  (overlap with previous "
                      f"version: {overlap}/5)")
            else:
                print(f"    top-5 neighbors: {top}")
            prev_top = top

        print(f"\nversions published: {registry.versions('go')}")
        print("lineage recorded per snapshot "
              "(parent_version / mode / delta stats):")
        for v in registry.versions("go"):
            _, _, _, meta = registry.get("go", "transe", v)
            lin = meta["lineage"]
            delta = lin["delta"] or {}
            print(f"  {v}: mode={lin['mode']:11s} "
                  f"parent={lin['parent_version']} "
                  f"churn={delta.get('churn_fraction', '-')}")
        print("\nembeddings for EVERY version remain downloadable "
              "(ontology-evolution studies):")
        for v in registry.versions("go"):
            ids, _, emb, _ = registry.get("go", "transe", v)
            print(f"  {v}: {len(ids)} classes, table {emb.shape}")
    print("\nOK")


if __name__ == "__main__":
    main()
