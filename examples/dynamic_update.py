"""Dynamic knowledge: the paper's core value proposition, end to end.

Simulates a GO release channel evolving over four versions (terms added,
obsoleted, edges rewired — like GO's monthly releases). The updater polls;
on checksum change it retrains and republishes; unchanged polls are no-ops.
Then demonstrates the knowledge-evolution study the paper enables: tracking
a term's neighborhood drift across versions.

    PYTHONPATH=src python examples/dynamic_update.py
"""
import sys
import tempfile
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.registry import EmbeddingRegistry
from repro.core.serving import ServingEngine
from repro.core.updater import Updater, poll_loop
from repro.kge.train import TrainConfig
from repro.ontology import obo
from repro.ontology.synthetic import GO_SPEC, release_series


class DirectoryChannel:
    """Mimics polling https://release.geneontology.org/ — a directory of
    OBO releases the cron job downloads into."""

    def __init__(self, name, directory):
        from repro.core.updater import FileReleaseChannel
        self._ch = FileReleaseChannel(name, directory)
        self.name = name

    def latest(self):
        return self._ch.latest()


def main():
    series = release_series(GO_SPEC, n_versions=4, seed=0, n_terms=300)
    with tempfile.TemporaryDirectory() as td:
        releases = Path(td) / "releases"
        releases.mkdir()
        registry = EmbeddingRegistry(Path(td) / "registry")
        engine = ServingEngine(registry)
        updater = Updater(registry, engine=engine,
                          models=("transe", "distmult"), dim=64,
                          train_cfg=TrainConfig(batch_size=256, num_negs=8),
                          steps_override=80)
        channel = DirectoryChannel("go", releases)

        track = series[0][1].entities[7]      # a class present from v1
        print(f"tracking neighborhood of {track} "
              f"({series[0][1].terms[track].label!r})\n")

        prev_top = None
        for tag, kg in series:
            # the "download" the cron job would do:
            obo.save_obo(kg, releases / f"{tag}.obo", header_version=tag)

            # poll twice: first sees the change, second is a no-op
            reports = poll_loop(updater, [channel], iterations=2)
            assert reports[0].changed and not reports[1].changed
            print(f"release {tag}: {kg.num_entities} classes -> retrained "
                  f"{reports[0].trained_models} in {reports[0].wall_s:.1f}s "
                  f"(second poll: no-op)")

            top = [c.identifier for c in
                   engine.closest_concepts("go", "transe", track, k=5)]
            if prev_top is not None:
                overlap = len(set(top) & set(prev_top))
                print(f"    top-5 neighbors: {top}  (overlap with previous "
                      f"version: {overlap}/5)")
            else:
                print(f"    top-5 neighbors: {top}")
            prev_top = top

        print(f"\nversions published: {registry.versions('go')}")
        print("embeddings for EVERY version remain downloadable "
              "(ontology-evolution studies):")
        for v in registry.versions("go"):
            ids, _, emb, _ = registry.get("go", "transe", v)
            print(f"  {v}: {len(ids)} classes, table {emb.shape}")
    print("\nOK")


if __name__ == "__main__":
    main()
