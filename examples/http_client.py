"""End-to-end HTTP demo: publish a synthetic ontology, stand up the
stdlib HTTP service over the gateway, and exercise every paper endpoint
through real sockets — including the ETag/304 conditional re-fetch and
the chunked streaming download.

Run:
    PYTHONPATH=src python examples/http_client.py
"""
from __future__ import annotations

import http.client
import json
import tempfile
import urllib.error
import urllib.parse
import urllib.request

import numpy as np


def main():
    from repro.api import Gateway, serve_http
    from repro.core.registry import EmbeddingRegistry
    from repro.core.serving import ServingEngine

    # -- publish two releases of a synthetic GO snapshot ---------------- #
    td = tempfile.mkdtemp(prefix="biokg-http-")
    registry = EmbeddingRegistry(td)
    n, d = 500, 64
    ids = [f"GO:{i:07d}" for i in range(n)]
    labels = [f"synthetic term {i}" for i in range(n)]
    for version, seed in (("2025-01", 0), ("2025-02", 1)):
        emb = np.random.default_rng(seed).standard_normal((n, d)) \
            .astype(np.float32)
        registry.publish("go", version, "transe", ids, labels, emb,
                         ontology_checksum=f"ck-{version}",
                         hyperparameters={"dim": d})
    engine = ServingEngine(registry)
    gateway = Gateway(engine, flush_after_ms=2.0)

    # -- the HTTP service (ephemeral port; daemon accept thread) -------- #
    server = serve_http(gateway, port=0, stream_page_rows=200)
    base = server.url
    print(f"[http] serving {base} over registry {td}")

    def get(path, headers=None):
        req = urllib.request.Request(base + path, headers=headers or {})
        try:
            with urllib.request.urlopen(req, timeout=30) as r:
                return r.status, dict(r.headers), r.read()
        except urllib.error.HTTPError as e:
            return e.code, dict(e.headers), e.read()

    # -- the five paper endpoints over GET ------------------------------ #
    _, _, body = get(f"/get-vector/go/transe?query={ids[3]}")
    vec = json.loads(body)
    print(f"[http] get-vector {vec['identifier']}: dim={len(vec['vector'])} "
          f"version={vec['version']}")

    _, _, body = get(f"/sim/go/transe?a={ids[0]}&b={ids[1]}")
    print(f"[http] sim({ids[0]}, {ids[1]}) = {json.loads(body)['score']:.4f}")

    _, _, body = get(f"/closest-concepts/go/transe?query={ids[0]}&k=3")
    for hit in json.loads(body)["results"]:
        print(f"[http]   top-k: {hit['identifier']} {hit['score']:.4f} "
              f"{hit['label']}")

    prefix = urllib.parse.quote("synthetic term 42")
    _, _, body = get(f"/autocomplete/go/transe?prefix={prefix}")
    print(f"[http] autocomplete: {json.loads(body)['completions'][:3]}")

    # -- download: page + conditional re-fetch (ETag -> 304) ------------ #
    status, headers, body = get("/download/go/transe?version=2025-02"
                                "&offset=0&limit=100")
    page = json.loads(body)
    print(f"[http] download page: {len(page['rows'])}/{page['total']} rows, "
          f"status={status}, etag={headers['ETag']}")
    status, _, body = get("/download/go/transe?version=2025-02"
                          "&offset=0&limit=100",
                          headers={"If-None-Match": headers["ETag"]})
    print(f"[http] conditional re-fetch: status={status} "
          f"(body={len(body)} bytes — no kernel, no index, no JSON)")

    # -- streaming download: chunked, never the full body in memory ----- #
    status, headers, body = get("/download/go/transe?stream=true")
    table = json.loads(body)
    print(f"[http] streamed download: {len(table)} classes, "
          f"transfer-encoding={headers.get('Transfer-Encoding')}, "
          f"largest chunk {server.http_stats['max_chunk_bytes']:,} B of "
          f"{len(body):,} B total")

    # -- structured errors become real HTTP statuses -------------------- #
    status, _, body = get("/sim/mars/transe?a=x&b=y")
    err = json.loads(body)
    print(f"[http] error mapping: HTTP {status} code={err['code']}")
    status, _, body = get("/no/such/route")
    print(f"[http] unknown route: HTTP {status} "
          f"code={json.loads(body)['code']}")

    # -- keep-alive: many requests down one connection ------------------ #
    conn = http.client.HTTPConnection("127.0.0.1", server.port, timeout=30)
    for i in range(5):
        conn.request("GET", f"/sim/go/transe?a={ids[i]}&b={ids[i + 1]}")
        conn.getresponse().read()
    conn.close()
    print("[http] keep-alive: 5 requests on one connection")

    # -- ops: per-route latency histograms in /stats -------------------- #
    _, _, body = get("/stats")
    stats = json.loads(body)
    for route, hist in sorted(stats["latency"].items()):
        print(f"[http] latency[{route}]: n={hist['count']} "
              f"p50={hist['p50_ms']}ms p99={hist['p99_ms']}ms")
    sched = stats["scheduler"]["latency_ms"]
    print(f"[http] scheduler submit->resolve: n={sched['count']} "
          f"p50={sched['p50_ms']}ms")
    print(f"[http] transport: {server.http_stats}")

    server.close()
    gateway.close()
    print("[http] done")


if __name__ == "__main__":
    main()
