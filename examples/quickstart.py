"""Quickstart: the whole Bio-KGvec2go loop in one script.

Generates a small synthetic GO, trains all six KGE models (paper config:
dim=200, capped steps for CPU), publishes versioned snapshots with PROV
metadata, and exercises the three API endpoints.

    PYTHONPATH=src python examples/quickstart.py
"""
import json
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.registry import EmbeddingRegistry
from repro.core.serving import ServingEngine
from repro.core.updater import PAPER_MODELS, Updater
from repro.kge.train import TrainConfig
from repro.ontology.synthetic import GO_SPEC, generate


def main():
    print("=== Bio-KGvec2go quickstart ===")
    kg = generate(GO_SPEC, seed=0, n_terms=500)
    print(f"synthetic GO: {kg.num_entities} classes, {kg.num_triples} triples, "
          f"relations={kg.relations}")

    with tempfile.TemporaryDirectory() as td:
        registry = EmbeddingRegistry(td)
        updater = Updater(
            registry, models=PAPER_MODELS, dim=200,
            train_cfg=TrainConfig(batch_size=256, num_negs=16, lr=1e-2),
            steps_override=60,             # CPU cap; paper runs 100 epochs
        )

        class Release:
            name = "go"
            def latest(self):
                return "2023-01-01", kg

        print("\n-- update pipeline: train + publish all six models --")
        report = updater.run_once(Release())
        for m, d in report.details.items():
            print(f"  {m:10s} loss={d['final_loss']:8.4f} "
                  f"{d['triples_per_s']:>10,.0f} triples/s")

        engine = ServingEngine(registry)

        print("\n-- endpoint 1: download --")
        payload = json.loads(engine.download("go", "transe"))
        some_id = kg.entities[10]
        print(f"  {len(payload)} classes, dim={len(payload[some_id])}; "
              f"{some_id} -> {payload[some_id][:4]}...")

        print("\n-- endpoint 2: similarity (ids and normalized labels) --")
        a, b = kg.entities[10], kg.entities[20]
        print(f"  sim({a}, {b}) = "
              f"{engine.similarity('go', 'transe', a, b):+.4f}")
        label = kg.terms[a].label
        print(f"  sim('  {label.upper()}  ', {b}) = "
              f"{engine.similarity('go', 'transe', '  ' + label.upper(), b):+.4f}"
              f"   (label, case/whitespace-normalized)")

        print("\n-- endpoint 3: top-10 closest concepts --")
        for c in engine.closest_concepts("go", "transe", a, k=10)[:5]:
            print(f"  {c.score:+.4f}  {c.identifier}  {c.label[:44]:44s} {c.url}")

        print("\n-- provenance --")
        _, _, _, meta = registry.get("go", "transe")
        print(f"  version={meta['version']} checksum={meta['ontology_checksum'][:12]}... "
              f"PROV agent/activity recorded: {sorted(meta['prov'])[:4]}...")
    print("\nOK")


if __name__ == "__main__":
    main()
