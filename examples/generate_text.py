"""Greedy generation with the serving path — prefill builds the KV cache
(with headroom), then serve_step decodes token by token, exercising the
same in-place cache machinery the decode_32k dry-run lowers (works for any
zoo arch; SSM/hybrid archs carry recurrent state instead of KV).

    PYTHONPATH=src python examples/generate_text.py --arch recurrentgemma-2b \
        --steps 24
"""
import argparse
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.models import get_model
from repro.models.steps import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-72b")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--int8-kv", action="store_true")
    args = ap.parse_args()

    cfg, model = get_model(args.arch, reduced=True)
    if args.int8_kv:
        cfg = cfg.with_(kv_cache_dtype="int8")
        from repro.models import build
        model = build(cfg)
    print(f"[gen] {cfg.arch_id} ({cfg.n_params()/1e6:.1f}M params"
          f"{', int8 KV' if args.int8_kv else ''})")

    params = model.init(jax.random.key(0))
    B = 2
    max_len = args.prompt_len + args.steps
    prompt = jax.random.randint(jax.random.key(1), (B, args.prompt_len),
                                0, cfg.vocab, jnp.int32)
    batch = {"tokens": prompt, "labels": prompt}

    t0 = time.perf_counter()
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len=max_len))(params, batch)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    print(f"[gen] prefill({args.prompt_len} tokens) "
          f"{time.perf_counter()-t0:.2f}s")

    step = jax.jit(make_serve_step(model), donate_argnums=(1,))
    seq = [tok]
    t0 = time.perf_counter()
    base = prompt.shape[1] if cfg.family != "vlm" else (
        prompt.shape[1] + 0)
    for i in range(args.steps - 1):
        pos = jnp.asarray(base + i, jnp.int32)
        tok, cache = step(params, cache, tok, pos)
        seq.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    out = jnp.concatenate(seq, axis=1)
    print(f"[gen] {args.steps-1} decode steps in {dt:.2f}s "
          f"({(args.steps-1)*B/dt:.1f} tok/s on 1 CPU core)")
    print(f"[gen] continuation ids (seq 0): {out[0].tolist()}")
    assert jnp.all((out >= 0) & (out < cfg.padded_vocab))
    print("OK")


if __name__ == "__main__":
    main()
