"""Beyond the paper: Bio-KGvec2go's API is model-agnostic.

The paper serves KGE snapshots; nothing in the serving stack cares where
the vectors came from. Here we register a *transformer's* token-embedding
table (one of the assigned zoo architectures, reduced for CPU) as a
versioned snapshot and serve similarity / top-k over it through the exact
same registry + engine + PROV path — demonstrating the framework's
"versioned embedding serving" layer generalizes to any model in the zoo.

    PYTHONPATH=src python examples/serve_llm_embeddings.py [--arch qwen2-72b]
"""
import argparse
import sys
import tempfile
from pathlib import Path

import jax
import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.registry import EmbeddingRegistry
from repro.core.serving import ServingEngine
from repro.models import get_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-72b")
    args = ap.parse_args()

    cfg, model = get_model(args.arch, reduced=True)
    print(f"building {cfg.arch_id} ({cfg.n_params()/1e6:.1f}M params, "
          f"reduced config)")
    params = model.init(jax.random.key(0))
    table = np.asarray(params["embed"], np.float32)[: cfg.vocab]

    ids = [f"tok:{i:05d}" for i in range(cfg.vocab)]
    labels = [f"token {i}" for i in range(cfg.vocab)]

    with tempfile.TemporaryDirectory() as td:
        registry = EmbeddingRegistry(td)
        registry.publish(
            ontology=cfg.arch_id, version="init-0", model_name="token-embed",
            entity_ids=ids, labels=labels, embeddings=table,
            ontology_checksum="n/a (model weights)",
            hyperparameters={"dim": cfg.d_model, "vocab": cfg.vocab,
                             "source": cfg.source},
        )
        engine = ServingEngine(registry)
        print(f"published {table.shape} token-embedding table as "
              f"'{cfg.arch_id}/init-0/token-embed'")

        s = engine.similarity(cfg.arch_id, "token-embed", "tok:00010",
                              "tok:00020")
        print(f"similarity(tok 10, tok 20) = {s:+.4f}")
        top = engine.closest_concepts(cfg.arch_id, "token-embed",
                                      "tok:00010", k=5)
        print("top-5 closest tokens to tok:00010:")
        for c in top:
            print(f"  {c.score:+.4f}  {c.identifier}")
    print("\nOK — same 3-endpoint API, arbitrary model's entity space")


if __name__ == "__main__":
    main()
